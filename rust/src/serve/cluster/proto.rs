//! Cluster message payloads and their std-only wire codec.
//!
//! Payload encoding is deliberately dumb: little-endian fixed-width
//! integers, length-prefixed byte strings, and **f64 bit patterns** for
//! grid data — bit patterns, not decimal round-trips, because the whole
//! point of the fleet is that a distributed evolution stays *bitwise*
//! equal to the single-process evolver. Enums with existing
//! `Display`/`FromStr` impls ([`KernelMethod`], [`Engine`]) travel as
//! strings so the wire form can never drift from the CLI's vocabulary.
//!
//! Message kinds (the `kind` field of the frame header):
//!
//! | kind | message       | direction            | payload                          |
//! |------|---------------|----------------------|----------------------------------|
//! | 1    | `Ping`        | coordinator → node   | empty                            |
//! | 2    | `Pong`        | node → coordinator   | [`NodeStatus`]                   |
//! | 3    | `EvolveChunk` | coordinator → node   | [`ChunkRequest`] (spec + tile)   |
//! | 4    | `ChunkOk`     | node → coordinator   | [`ChunkReply`] (evolved tile)    |
//! | 5    | `ChunkErr`    | node → coordinator   | id + error string                |
//! | 6    | `Shutdown`    | coordinator → node   | empty                            |
//! | 7    | `ShutdownAck` | node → coordinator   | empty                            |
//!
//! Versioning policy (see CONTRIBUTING.md): any change to these
//! payloads or kinds bumps [`super::frame::VERSION`]; a node and
//! coordinator of different versions refuse each other at the first
//! frame header.

use super::frame;
use crate::kir::Engine;
use crate::serve::scheduler::KernelMethod;
use crate::stencil::{DenseGrid, StencilKind, StencilSpec};
use std::io::{Read, Write};
use std::time::Duration;

/// Message-kind constants (frame header `kind` field).
pub const KIND_PING: u16 = 1;
/// See [`KIND_PING`].
pub const KIND_PONG: u16 = 2;
/// See [`KIND_PING`].
pub const KIND_EVOLVE_CHUNK: u16 = 3;
/// See [`KIND_PING`].
pub const KIND_CHUNK_OK: u16 = 4;
/// See [`KIND_PING`].
pub const KIND_CHUNK_ERR: u16 = 5;
/// See [`KIND_PING`].
pub const KIND_SHUTDOWN: u16 = 6;
/// See [`KIND_PING`].
pub const KIND_SHUTDOWN_ACK: u16 = 7;

/// Append-only payload writer (little-endian throughout).
#[derive(Default)]
pub struct WireWriter {
    /// The encoded payload so far.
    pub buf: Vec<u8>,
}

impl WireWriter {
    /// Fresh empty writer.
    pub fn new() -> WireWriter {
        WireWriter::default()
    }

    /// Append a `u8`.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u16` (LE).
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32` (LE).
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64` (LE).
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` as its IEEE-754 bit pattern (LE) — exact.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Append a length-prefixed byte string.
    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Append a grid: dims, shape (u64 each), then data as f64 bits.
    pub fn grid(&mut self, g: &DenseGrid) {
        self.u8(g.shape.len() as u8);
        for &n in &g.shape {
            self.u64(n as u64);
        }
        for &v in &g.data {
            self.f64(v);
        }
    }
}

/// Cursor-style payload reader with bounds-checked accessors.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    /// Reader over a payload.
    pub fn new(buf: &'a [u8]) -> WireReader<'a> {
        WireReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.buf.len(),
            "short payload: wanted {n} byte(s) at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a `u16` (LE).
    pub fn u16(&mut self) -> anyhow::Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    /// Read a `u32` (LE).
    pub fn u32(&mut self) -> anyhow::Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    /// Read a `u64` (LE).
    pub fn u64(&mut self) -> anyhow::Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    /// Read an `f64` bit pattern.
    pub fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a length-prefixed byte string.
    pub fn bytes(&mut self) -> anyhow::Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> anyhow::Result<String> {
        Ok(String::from_utf8(self.bytes()?.to_vec())
            .map_err(|e| anyhow::anyhow!("non-UTF-8 string in payload: {e}"))?)
    }

    /// Read a grid written by [`WireWriter::grid`].
    pub fn grid(&mut self) -> anyhow::Result<DenseGrid> {
        let dims = self.u8()? as usize;
        anyhow::ensure!(dims == 2 || dims == 3, "grid dims {dims} not in {{2, 3}}");
        let mut shape = Vec::with_capacity(dims);
        for _ in 0..dims {
            let n = self.u64()? as usize;
            anyhow::ensure!(n >= 1, "empty grid dimension");
            shape.push(n);
        }
        let len: usize = shape.iter().product();
        // guard the allocation against a forged shape before reading
        anyhow::ensure!(
            len.checked_mul(8).map(|b| b <= frame::MAX_FRAME_LEN).unwrap_or(false),
            "grid shape {shape:?} larger than a frame can carry"
        );
        let mut data = Vec::with_capacity(len);
        for _ in 0..len {
            data.push(self.f64()?);
        }
        Ok(DenseGrid { shape, data })
    }

    /// Error unless the whole payload was consumed (catches trailing
    /// garbage from a confused encoder).
    pub fn finish(self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "payload has {} unread trailing byte(s)",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

fn encode_spec(w: &mut WireWriter, spec: StencilSpec) {
    w.u8(spec.dims as u8);
    w.u8(spec.order as u8);
    w.u8(match spec.kind {
        StencilKind::Box => 0,
        StencilKind::Star => 1,
        StencilKind::Diagonal => 2,
    });
}

fn decode_spec(r: &mut WireReader<'_>) -> anyhow::Result<StencilSpec> {
    let dims = r.u8()? as usize;
    let order = r.u8()? as usize;
    let kind = match r.u8()? {
        0 => StencilKind::Box,
        1 => StencilKind::Star,
        2 => StencilKind::Diagonal,
        other => anyhow::bail!("unknown stencil kind tag {other}"),
    };
    StencilSpec::new(dims, order, kind)
}

/// A worker node's self-description (the `Pong` payload) — the cluster
/// analogue of the `/healthz` fields.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeStatus {
    /// Worker threads in the node's pool.
    pub workers: usize,
    /// Host engine the node compiles shard kernels for.
    pub engine: Engine,
    /// Chunks this node has evolved since it started.
    pub chunks_served: u64,
}

/// One slab-evolution RPC: evolve `tile` by `steps` fused time steps.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkRequest {
    /// Correlation id (the coordinator uses the shard index).
    pub id: u64,
    /// The stencil.
    pub spec: StencilSpec,
    /// Kernel flavour.
    pub method: KernelMethod,
    /// Host execution engine for KIR kernels.
    pub engine: Engine,
    /// Fused time steps to advance (the tile carries `order × steps`
    /// ghosts).
    pub steps: usize,
    /// Local shard hint for the node's in-process evolver (0 = let the
    /// node decide). Results are bitwise independent of this value.
    pub local_shards: usize,
    /// The slab tile (owned rows + ghosts).
    pub tile: DenseGrid,
}

/// A successful chunk evolution.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkReply {
    /// Correlation id echoed from the request.
    pub id: u64,
    /// The evolved tile (same shape as the request's).
    pub tile: DenseGrid,
}

/// Every message the cluster protocol speaks.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Health probe.
    Ping,
    /// Health reply.
    Pong(NodeStatus),
    /// Evolve one slab tile.
    EvolveChunk(ChunkRequest),
    /// Slab evolved.
    ChunkOk(ChunkReply),
    /// Slab evolution failed node-side.
    ChunkErr {
        /// Correlation id echoed from the request.
        id: u64,
        /// The node-side error rendering.
        error: String,
    },
    /// Ask the node to stop accepting and exit its serve loop.
    Shutdown,
    /// Shutdown acknowledged (sent before the node closes).
    ShutdownAck,
}

impl Msg {
    /// Encode to (frame kind, payload bytes).
    pub fn encode(&self) -> (u16, Vec<u8>) {
        let mut w = WireWriter::new();
        let kind = match self {
            Msg::Ping => KIND_PING,
            Msg::Pong(st) => {
                w.u64(st.workers as u64);
                w.str(&st.engine.to_string());
                w.u64(st.chunks_served);
                KIND_PONG
            }
            Msg::EvolveChunk(req) => {
                w.u64(req.id);
                encode_spec(&mut w, req.spec);
                w.str(&req.method.to_string());
                w.str(&req.engine.to_string());
                w.u64(req.steps as u64);
                w.u64(req.local_shards as u64);
                w.grid(&req.tile);
                KIND_EVOLVE_CHUNK
            }
            Msg::ChunkOk(rep) => {
                w.u64(rep.id);
                w.grid(&rep.tile);
                KIND_CHUNK_OK
            }
            Msg::ChunkErr { id, error } => {
                w.u64(*id);
                w.str(error);
                KIND_CHUNK_ERR
            }
            Msg::Shutdown => KIND_SHUTDOWN,
            Msg::ShutdownAck => KIND_SHUTDOWN_ACK,
        };
        (kind, w.buf)
    }

    /// Decode from a frame's (kind, payload).
    pub fn decode(kind: u16, payload: &[u8]) -> anyhow::Result<Msg> {
        let mut r = WireReader::new(payload);
        let msg = match kind {
            KIND_PING => Msg::Ping,
            KIND_PONG => {
                let workers = r.u64()? as usize;
                let engine: Engine = r.str()?.parse()?;
                let chunks_served = r.u64()?;
                Msg::Pong(NodeStatus { workers, engine, chunks_served })
            }
            KIND_EVOLVE_CHUNK => {
                let id = r.u64()?;
                let spec = decode_spec(&mut r)?;
                let method: KernelMethod = r.str()?.parse()?;
                let engine: Engine = r.str()?.parse()?;
                let steps = r.u64()? as usize;
                let local_shards = r.u64()? as usize;
                let tile = r.grid()?;
                Msg::EvolveChunk(ChunkRequest {
                    id,
                    spec,
                    method,
                    engine,
                    steps,
                    local_shards,
                    tile,
                })
            }
            KIND_CHUNK_OK => {
                let id = r.u64()?;
                let tile = r.grid()?;
                Msg::ChunkOk(ChunkReply { id, tile })
            }
            KIND_CHUNK_ERR => {
                let id = r.u64()?;
                let error = r.str()?;
                Msg::ChunkErr { id, error }
            }
            KIND_SHUTDOWN => Msg::Shutdown,
            KIND_SHUTDOWN_ACK => Msg::ShutdownAck,
            other => anyhow::bail!("unknown message kind {other}"),
        };
        r.finish()?;
        Ok(msg)
    }
}

/// Send one message as a frame.
pub fn send_msg(w: &mut impl Write, msg: &Msg) -> anyhow::Result<usize> {
    let (kind, payload) = msg.encode();
    let n = frame::HEADER_LEN + payload.len();
    frame::send_frame(w, kind, &payload)?;
    Ok(n)
}

/// Outcome of one [`recv_msg`] poll (mirrors [`frame::Recv`]).
#[derive(Debug, Clone, PartialEq)]
pub enum MsgRecv {
    /// A decoded message and its total wire size in bytes.
    Msg(Msg, usize),
    /// Peer closed at a frame boundary.
    Eof,
    /// No bytes before the stream's read timeout.
    Idle,
}

/// Receive and decode one message (see [`frame::recv_frame`] for the
/// deadline/idle semantics).
pub fn recv_msg(r: &mut impl Read, deadline: Duration) -> anyhow::Result<MsgRecv> {
    Ok(match frame::recv_frame(r, deadline)? {
        frame::Recv::Frame(kind, payload) => {
            let n = frame::HEADER_LEN + payload.len();
            MsgRecv::Msg(Msg::decode(kind, &payload)?, n)
        }
        frame::Recv::Eof => MsgRecv::Eof,
        frame::Recv::Idle => MsgRecv::Idle,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) -> Msg {
        let (kind, payload) = msg.encode();
        Msg::decode(kind, &payload).unwrap()
    }

    #[test]
    fn every_message_roundtrips() {
        let tile = DenseGrid::verification_input(&[6, 5], 42);
        let msgs = [
            Msg::Ping,
            Msg::Pong(NodeStatus { workers: 4, engine: Engine::Simd, chunks_served: 17 }),
            Msg::EvolveChunk(ChunkRequest {
                id: 9,
                spec: StencilSpec::star2d(2),
                method: KernelMethod::Outer,
                engine: Engine::Compiled,
                steps: 3,
                local_shards: 2,
                tile: tile.clone(),
            }),
            Msg::ChunkOk(ChunkReply { id: 9, tile }),
            Msg::ChunkErr { id: 3, error: "tile too small".to_string() },
            Msg::Shutdown,
            Msg::ShutdownAck,
        ];
        for msg in &msgs {
            assert_eq!(&roundtrip(msg), msg);
        }
    }

    #[test]
    fn grid_payloads_are_bitwise_exact() {
        // values that decimal round-trips would mangle: subnormals,
        // negative zero, and full-precision irrationals
        let g = DenseGrid {
            shape: vec![2, 3],
            data: vec![f64::MIN_POSITIVE / 2.0, -0.0, std::f64::consts::PI, 1e-300, -3.5, 0.1],
        };
        let mut w = WireWriter::new();
        w.grid(&g);
        let mut r = WireReader::new(&w.buf);
        let back = r.grid().unwrap();
        r.finish().unwrap();
        assert_eq!(back.shape, g.shape);
        for (a, b) in back.data.iter().zip(&g.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Msg::decode(999, &[]).is_err());
        // trailing garbage after a valid Ping payload
        assert!(Msg::decode(KIND_PING, &[0xFF]).is_err());
        // truncated chunk payload
        let (kind, payload) = Msg::ChunkOk(ChunkReply {
            id: 1,
            tile: DenseGrid::verification_input(&[4, 4], 1),
        })
        .encode();
        assert!(Msg::decode(kind, &payload[..payload.len() - 5]).is_err());
        // forged giant shape must refuse before allocating
        let mut w = WireWriter::new();
        w.u64(1); // id
        w.u8(2);
        w.u64(u32::MAX as u64);
        w.u64(u32::MAX as u64);
        assert!(Msg::decode(KIND_CHUNK_OK, &w.buf).is_err());
    }
}
