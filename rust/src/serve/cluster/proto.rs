//! Cluster message payloads and their std-only wire codec.
//!
//! Payload encoding is deliberately dumb: little-endian fixed-width
//! integers, length-prefixed byte strings, and **f64 bit patterns** for
//! grid data — bit patterns, not decimal round-trips, because the whole
//! point of the fleet is that a distributed evolution stays *bitwise*
//! equal to the single-process evolver. Enums with existing
//! `Display`/`FromStr` impls ([`KernelMethod`], [`Engine`]) travel as
//! strings so the wire form can never drift from the CLI's vocabulary.
//!
//! Message kinds (the `kind` field of the frame header):
//!
//! | kind | message       | direction            | payload                          |
//! |------|---------------|----------------------|----------------------------------|
//! | 1    | `Ping`        | coordinator → node   | empty                            |
//! | 2    | `Pong`        | node → coordinator   | [`NodeStatus`]                   |
//! | 3    | `EvolveChunk` | coordinator → node   | [`ChunkRequest`] (spec + tile)   |
//! | 4    | `ChunkOk`     | node → coordinator   | [`ChunkReply`] (evolved tile)    |
//! | 5    | `ChunkErr`    | node → coordinator   | id + error string                |
//! | 6    | `Shutdown`    | coordinator → node   | empty                            |
//! | 7    | `ShutdownAck` | node → coordinator   | empty                            |
//! | 8    | `EvolvePlan`  | coordinator → node   | [`PlanRequest`] (plan + tiles)   |
//! | 9    | `PlanReady`   | node → coordinator   | epoch                            |
//! | 10   | `PlanStart`   | coordinator → node   | epoch                            |
//! | 11   | `PlanDone`    | node → coordinator   | [`PlanDoneMsg`] (tiles + stats)  |
//! | 12   | `PlanErr`     | node → coordinator   | epoch + error string             |
//! | 13   | `HaloPush`    | node → node          | [`HaloBand`] (one boundary band) |
//! | 14   | `HaloAck`     | node → node          | band tags echoed                 |
//!
//! Kinds 8–14 (protocol version 2) carry the peer-to-peer exchange path:
//! the coordinator distributes one [`ExchangePlan`] per evolution, waits
//! for every node's `PlanReady` (so band staging is registered before any
//! band can arrive), fires `PlanStart`, and nodes then run every fused
//! round locally — pushing only the `order·T`-deep boundary bands to
//! neighbour nodes while computing slab interiors.
//!
//! Versioning policy (see CONTRIBUTING.md): any change to these
//! payloads or kinds bumps [`super::frame::VERSION`]; a node and
//! coordinator of different versions refuse each other at the first
//! frame header.

use super::frame;
use crate::kir::Engine;
use crate::serve::partition::{Partition, Slab};
use crate::serve::scheduler::KernelMethod;
use crate::stencil::{DenseGrid, StencilKind, StencilSpec};
use std::io::{Read, Write};
use std::time::Duration;

/// Message-kind constants (frame header `kind` field).
pub const KIND_PING: u16 = 1;
/// See [`KIND_PING`].
pub const KIND_PONG: u16 = 2;
/// See [`KIND_PING`].
pub const KIND_EVOLVE_CHUNK: u16 = 3;
/// See [`KIND_PING`].
pub const KIND_CHUNK_OK: u16 = 4;
/// See [`KIND_PING`].
pub const KIND_CHUNK_ERR: u16 = 5;
/// See [`KIND_PING`].
pub const KIND_SHUTDOWN: u16 = 6;
/// See [`KIND_PING`].
pub const KIND_SHUTDOWN_ACK: u16 = 7;
/// See [`KIND_PING`].
pub const KIND_EVOLVE_PLAN: u16 = 8;
/// See [`KIND_PING`].
pub const KIND_PLAN_READY: u16 = 9;
/// See [`KIND_PING`].
pub const KIND_PLAN_START: u16 = 10;
/// See [`KIND_PING`].
pub const KIND_PLAN_DONE: u16 = 11;
/// See [`KIND_PING`].
pub const KIND_PLAN_ERR: u16 = 12;
/// See [`KIND_PING`].
pub const KIND_HALO_PUSH: u16 = 13;
/// See [`KIND_PING`].
pub const KIND_HALO_ACK: u16 = 14;

/// Append-only payload writer (little-endian throughout).
#[derive(Default)]
pub struct WireWriter {
    /// The encoded payload so far.
    pub buf: Vec<u8>,
}

impl WireWriter {
    /// Fresh empty writer.
    pub fn new() -> WireWriter {
        WireWriter::default()
    }

    /// Append a `u8`.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u16` (LE).
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32` (LE).
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64` (LE).
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` as its IEEE-754 bit pattern (LE) — exact.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Append a length-prefixed byte string.
    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Append a grid: dims, shape (u64 each), then data as f64 bits.
    pub fn grid(&mut self, g: &DenseGrid) {
        self.u8(g.shape.len() as u8);
        for &n in &g.shape {
            self.u64(n as u64);
        }
        for &v in &g.data {
            self.f64(v);
        }
    }
}

/// Cursor-style payload reader with bounds-checked accessors.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    /// Reader over a payload.
    pub fn new(buf: &'a [u8]) -> WireReader<'a> {
        WireReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.buf.len(),
            "short payload: wanted {n} byte(s) at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a `u16` (LE).
    pub fn u16(&mut self) -> anyhow::Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    /// Read a `u32` (LE).
    pub fn u32(&mut self) -> anyhow::Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    /// Read a `u64` (LE).
    pub fn u64(&mut self) -> anyhow::Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    /// Read an `f64` bit pattern.
    pub fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a length-prefixed byte string.
    pub fn bytes(&mut self) -> anyhow::Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> anyhow::Result<String> {
        Ok(String::from_utf8(self.bytes()?.to_vec())
            .map_err(|e| anyhow::anyhow!("non-UTF-8 string in payload: {e}"))?)
    }

    /// Read a grid written by [`WireWriter::grid`].
    pub fn grid(&mut self) -> anyhow::Result<DenseGrid> {
        let dims = self.u8()? as usize;
        anyhow::ensure!(dims == 2 || dims == 3, "grid dims {dims} not in {{2, 3}}");
        let mut shape = Vec::with_capacity(dims);
        for _ in 0..dims {
            let n = self.u64()? as usize;
            anyhow::ensure!(n >= 1, "empty grid dimension");
            shape.push(n);
        }
        let len: usize = shape.iter().product();
        // guard the allocation against a forged shape before reading
        anyhow::ensure!(
            len.checked_mul(8).map(|b| b <= frame::MAX_FRAME_LEN).unwrap_or(false),
            "grid shape {shape:?} larger than a frame can carry"
        );
        let mut data = Vec::with_capacity(len);
        for _ in 0..len {
            data.push(self.f64()?);
        }
        Ok(DenseGrid { shape, data })
    }

    /// Error unless the whole payload was consumed (catches trailing
    /// garbage from a confused encoder).
    pub fn finish(self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "payload has {} unread trailing byte(s)",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

fn encode_spec(w: &mut WireWriter, spec: StencilSpec) {
    w.u8(spec.dims as u8);
    w.u8(spec.order as u8);
    w.u8(match spec.kind {
        StencilKind::Box => 0,
        StencilKind::Star => 1,
        StencilKind::Diagonal => 2,
    });
}

fn decode_spec(r: &mut WireReader<'_>) -> anyhow::Result<StencilSpec> {
    let dims = r.u8()? as usize;
    let order = r.u8()? as usize;
    let kind = match r.u8()? {
        0 => StencilKind::Box,
        1 => StencilKind::Star,
        2 => StencilKind::Diagonal,
        other => anyhow::bail!("unknown stencil kind tag {other}"),
    };
    StencilSpec::new(dims, order, kind)
}

/// A worker node's self-description (the `Pong` payload) — the cluster
/// analogue of the `/healthz` fields.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeStatus {
    /// Worker threads in the node's pool.
    pub workers: usize,
    /// Host engine the node compiles shard kernels for.
    pub engine: Engine,
    /// Chunks this node has evolved since it started.
    pub chunks_served: u64,
}

/// One slab-evolution RPC: evolve `tile` by `steps` fused time steps.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkRequest {
    /// Correlation id (the coordinator uses the shard index).
    pub id: u64,
    /// The stencil.
    pub spec: StencilSpec,
    /// Kernel flavour.
    pub method: KernelMethod,
    /// Host execution engine for KIR kernels.
    pub engine: Engine,
    /// Fused time steps to advance (the tile carries `order × steps`
    /// ghosts).
    pub steps: usize,
    /// Local shard hint for the node's in-process evolver (0 = let the
    /// node decide). Results are bitwise independent of this value.
    pub local_shards: usize,
    /// The slab tile (owned rows + ghosts).
    pub tile: DenseGrid,
}

/// A successful chunk evolution.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkReply {
    /// Correlation id echoed from the request.
    pub id: u64,
    /// The evolved tile (same shape as the request's).
    pub tile: DenseGrid,
}

/// Which side of the *receiving* shard a halo band fills.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BandSide {
    /// The band fills the receiver's lower ghost rows (it was extracted
    /// from the receiver's lower neighbour).
    FromLower,
    /// The band fills the receiver's upper ghost rows.
    FromUpper,
}

impl BandSide {
    fn to_u8(self) -> u8 {
        match self {
            BandSide::FromLower => 0,
            BandSide::FromUpper => 1,
        }
    }

    fn from_u8(v: u8) -> anyhow::Result<BandSide> {
        match v {
            0 => Ok(BandSide::FromLower),
            1 => Ok(BandSide::FromUpper),
            other => anyhow::bail!("unknown band side tag {other}"),
        }
    }
}

/// The per-evolution exchange plan the coordinator distributes once at
/// placement time: everything a node needs to run every fused round
/// locally and exchange halo bands directly with peer nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct ExchangePlan {
    /// Unique id of this evolution; tags every band so frames from a
    /// stale or concurrent plan can never be misapplied.
    pub epoch: u64,
    /// The stencil.
    pub spec: StencilSpec,
    /// Kernel flavour.
    pub method: KernelMethod,
    /// Host execution engine for KIR kernels.
    pub engine: Engine,
    /// Total time steps of the evolution.
    pub steps: usize,
    /// Fused steps per round `T` (the last round may be shorter); the
    /// partition's halo is `order · T`.
    pub fuse: usize,
    /// Local shard hint for each node's in-process evolver (0 = let the
    /// node decide). Results are bitwise independent of this value.
    pub local_shards: usize,
    /// How long a node waits for an expected band before declaring the
    /// plan failed.
    pub band_timeout_ms: u64,
    /// The slab decomposition (identical on every node).
    pub part: Partition,
    /// Owning node index per shard (`owners[s]` indexes `peers`).
    pub owners: Vec<usize>,
    /// Peer listen address per node index (the same listeners the
    /// coordinator dialed).
    pub peers: Vec<String>,
    /// The receiving node's own index into `peers`/`owners`.
    pub self_node: usize,
}

/// `EvolvePlan` payload: the shared plan plus the receiving node's
/// assigned `(shard, tile)` pairs.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanRequest {
    /// The shared exchange plan (with `self_node` set per recipient).
    pub plan: ExchangePlan,
    /// This node's slab tiles (owned rows + ghosts), keyed by shard.
    pub tiles: Vec<(u64, DenseGrid)>,
}

/// Node-side accounting for one completed plan, reported in `PlanDone`
/// and aggregated by the coordinator into the overlap metrics.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PlanStats {
    /// Fused rounds executed.
    pub rounds: u64,
    /// Halo bands pushed to peer nodes (locally deposited bands excluded).
    pub bands_sent: u64,
    /// Wire bytes of pushed bands (headers included).
    pub band_bytes_sent: u64,
    /// Wire bytes of bands received from peer nodes.
    pub band_bytes_recv: u64,
    /// Exchange time hidden behind interior compute (bands in flight
    /// while the node was computing).
    pub exchange_hidden_seconds: f64,
    /// Exchange time *not* hidden: band extraction/send, blocked waits,
    /// and band application.
    pub exchange_visible_seconds: f64,
    /// Time spent in the sharded evolver (interior + boundary compute).
    pub compute_seconds: f64,
}

/// `PlanDone` payload: the node's evolved tiles plus its exchange stats.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanDoneMsg {
    /// Plan epoch echoed back.
    pub epoch: u64,
    /// Evolved `(shard, tile)` pairs (same shapes as assigned).
    pub tiles: Vec<(u64, DenseGrid)>,
    /// Node-side exchange accounting.
    pub stats: PlanStats,
}

/// One boundary band in flight between peer nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct HaloBand {
    /// Plan epoch.
    pub epoch: u64,
    /// Fused round the band belongs to (0-based).
    pub round: u64,
    /// Destination shard.
    pub shard: u64,
    /// Which ghost rows of the destination tile the band fills.
    pub side: BandSide,
    /// Band values, row-major, exactly `count · row_elems` f64s.
    pub data: Vec<f64>,
}

fn encode_f64s(w: &mut WireWriter, data: &[f64]) {
    w.u64(data.len() as u64);
    for &v in data {
        w.f64(v);
    }
}

fn decode_f64s(r: &mut WireReader<'_>) -> anyhow::Result<Vec<f64>> {
    let len = r.u64()? as usize;
    // guard the allocation against a forged length before reading
    anyhow::ensure!(
        len.checked_mul(8).map(|b| b <= frame::MAX_FRAME_LEN).unwrap_or(false),
        "f64 run of {len} value(s) larger than a frame can carry"
    );
    let mut data = Vec::with_capacity(len);
    for _ in 0..len {
        data.push(r.f64()?);
    }
    Ok(data)
}

fn encode_tiles(w: &mut WireWriter, tiles: &[(u64, DenseGrid)]) {
    w.u64(tiles.len() as u64);
    for (shard, tile) in tiles {
        w.u64(*shard);
        w.grid(tile);
    }
}

fn decode_tiles(r: &mut WireReader<'_>) -> anyhow::Result<Vec<(u64, DenseGrid)>> {
    let n = r.u64()? as usize;
    let mut tiles = Vec::new();
    for _ in 0..n {
        let shard = r.u64()?;
        let tile = r.grid()?;
        tiles.push((shard, tile));
    }
    Ok(tiles)
}

fn encode_plan(w: &mut WireWriter, plan: &ExchangePlan) {
    w.u64(plan.epoch);
    encode_spec(w, plan.spec);
    w.str(&plan.method.to_string());
    w.str(&plan.engine.to_string());
    w.u64(plan.steps as u64);
    w.u64(plan.fuse as u64);
    w.u64(plan.local_shards as u64);
    w.u64(plan.band_timeout_ms);
    w.u8(plan.part.shape.len() as u8);
    for &n in &plan.part.shape {
        w.u64(n as u64);
    }
    w.u64(plan.part.halo as u64);
    w.u64(plan.part.slabs.len() as u64);
    for slab in &plan.part.slabs {
        w.u64(slab.lo as u64);
        w.u64(slab.hi as u64);
        w.u64(slab.ghost_lo as u64);
        w.u64(slab.ghost_hi as u64);
    }
    w.u64(plan.owners.len() as u64);
    for &o in &plan.owners {
        w.u64(o as u64);
    }
    w.u64(plan.peers.len() as u64);
    for p in &plan.peers {
        w.str(p);
    }
    w.u64(plan.self_node as u64);
}

fn decode_plan(r: &mut WireReader<'_>) -> anyhow::Result<ExchangePlan> {
    let epoch = r.u64()?;
    let spec = decode_spec(r)?;
    let method: KernelMethod = r.str()?.parse()?;
    let engine: Engine = r.str()?.parse()?;
    let steps = r.u64()? as usize;
    let fuse = r.u64()? as usize;
    let local_shards = r.u64()? as usize;
    let band_timeout_ms = r.u64()?;
    let dims = r.u8()? as usize;
    anyhow::ensure!(dims == 2 || dims == 3, "plan shape dims {dims} not in {{2, 3}}");
    let mut shape = Vec::with_capacity(dims);
    for _ in 0..dims {
        shape.push(r.u64()? as usize);
    }
    let halo = r.u64()? as usize;
    let n_slabs = r.u64()? as usize;
    anyhow::ensure!(n_slabs >= 1, "plan with no slabs");
    let mut slabs = Vec::new();
    for _ in 0..n_slabs {
        let lo = r.u64()? as usize;
        let hi = r.u64()? as usize;
        let ghost_lo = r.u64()? as usize;
        let ghost_hi = r.u64()? as usize;
        anyhow::ensure!(lo < hi, "plan slab with empty row range [{lo}, {hi})");
        slabs.push(Slab { lo, hi, ghost_lo, ghost_hi });
    }
    let part = Partition { shape, halo, slabs };
    let n_owners = r.u64()? as usize;
    anyhow::ensure!(
        n_owners == part.slabs.len(),
        "plan has {n_owners} owner(s) for {} slab(s)",
        part.slabs.len()
    );
    let mut owners = Vec::new();
    for _ in 0..n_owners {
        owners.push(r.u64()? as usize);
    }
    let n_peers = r.u64()? as usize;
    let mut peers = Vec::new();
    for _ in 0..n_peers {
        peers.push(r.str()?);
    }
    let self_node = r.u64()? as usize;
    anyhow::ensure!(
        self_node < peers.len(),
        "plan self_node {self_node} out of range for {} peer(s)",
        peers.len()
    );
    anyhow::ensure!(
        owners.iter().all(|&o| o < peers.len()),
        "plan owner index out of range for {} peer(s)",
        peers.len()
    );
    Ok(ExchangePlan {
        epoch,
        spec,
        method,
        engine,
        steps,
        fuse,
        local_shards,
        band_timeout_ms,
        part,
        owners,
        peers,
        self_node,
    })
}

fn encode_stats(w: &mut WireWriter, st: &PlanStats) {
    w.u64(st.rounds);
    w.u64(st.bands_sent);
    w.u64(st.band_bytes_sent);
    w.u64(st.band_bytes_recv);
    w.f64(st.exchange_hidden_seconds);
    w.f64(st.exchange_visible_seconds);
    w.f64(st.compute_seconds);
}

fn decode_stats(r: &mut WireReader<'_>) -> anyhow::Result<PlanStats> {
    Ok(PlanStats {
        rounds: r.u64()?,
        bands_sent: r.u64()?,
        band_bytes_sent: r.u64()?,
        band_bytes_recv: r.u64()?,
        exchange_hidden_seconds: r.f64()?,
        exchange_visible_seconds: r.f64()?,
        compute_seconds: r.f64()?,
    })
}

/// Every message the cluster protocol speaks.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Health probe.
    Ping,
    /// Health reply.
    Pong(NodeStatus),
    /// Evolve one slab tile.
    EvolveChunk(ChunkRequest),
    /// Slab evolved.
    ChunkOk(ChunkReply),
    /// Slab evolution failed node-side.
    ChunkErr {
        /// Correlation id echoed from the request.
        id: u64,
        /// The node-side error rendering.
        error: String,
    },
    /// Ask the node to stop accepting and exit its serve loop.
    Shutdown,
    /// Shutdown acknowledged (sent before the node closes).
    ShutdownAck,
    /// Distribute one evolution's exchange plan plus the recipient's
    /// tiles.
    EvolvePlan(PlanRequest),
    /// The node has registered band staging for the plan's epoch and is
    /// ready to receive pushes.
    PlanReady {
        /// Plan epoch echoed back.
        epoch: u64,
    },
    /// All nodes are ready: run the plan's rounds.
    PlanStart {
        /// Plan epoch.
        epoch: u64,
    },
    /// The node finished every round of the plan.
    PlanDone(PlanDoneMsg),
    /// The plan failed node-side (band timeout, peer loss, …).
    PlanErr {
        /// Plan epoch echoed back.
        epoch: u64,
        /// The node-side error rendering.
        error: String,
    },
    /// One boundary band, node → node.
    HaloPush(HaloBand),
    /// Band receipt acknowledged (tags echoed).
    HaloAck {
        /// Plan epoch echoed from the push.
        epoch: u64,
        /// Round echoed from the push.
        round: u64,
        /// Destination shard echoed from the push.
        shard: u64,
        /// Side echoed from the push.
        side: BandSide,
    },
}

impl Msg {
    /// Encode to (frame kind, payload bytes).
    pub fn encode(&self) -> (u16, Vec<u8>) {
        let mut w = WireWriter::new();
        let kind = match self {
            Msg::Ping => KIND_PING,
            Msg::Pong(st) => {
                w.u64(st.workers as u64);
                w.str(&st.engine.to_string());
                w.u64(st.chunks_served);
                KIND_PONG
            }
            Msg::EvolveChunk(req) => {
                w.u64(req.id);
                encode_spec(&mut w, req.spec);
                w.str(&req.method.to_string());
                w.str(&req.engine.to_string());
                w.u64(req.steps as u64);
                w.u64(req.local_shards as u64);
                w.grid(&req.tile);
                KIND_EVOLVE_CHUNK
            }
            Msg::ChunkOk(rep) => {
                w.u64(rep.id);
                w.grid(&rep.tile);
                KIND_CHUNK_OK
            }
            Msg::ChunkErr { id, error } => {
                w.u64(*id);
                w.str(error);
                KIND_CHUNK_ERR
            }
            Msg::Shutdown => KIND_SHUTDOWN,
            Msg::ShutdownAck => KIND_SHUTDOWN_ACK,
            Msg::EvolvePlan(req) => {
                encode_plan(&mut w, &req.plan);
                encode_tiles(&mut w, &req.tiles);
                KIND_EVOLVE_PLAN
            }
            Msg::PlanReady { epoch } => {
                w.u64(*epoch);
                KIND_PLAN_READY
            }
            Msg::PlanStart { epoch } => {
                w.u64(*epoch);
                KIND_PLAN_START
            }
            Msg::PlanDone(done) => {
                w.u64(done.epoch);
                encode_tiles(&mut w, &done.tiles);
                encode_stats(&mut w, &done.stats);
                KIND_PLAN_DONE
            }
            Msg::PlanErr { epoch, error } => {
                w.u64(*epoch);
                w.str(error);
                KIND_PLAN_ERR
            }
            Msg::HaloPush(band) => {
                w.u64(band.epoch);
                w.u64(band.round);
                w.u64(band.shard);
                w.u8(band.side.to_u8());
                encode_f64s(&mut w, &band.data);
                KIND_HALO_PUSH
            }
            Msg::HaloAck { epoch, round, shard, side } => {
                w.u64(*epoch);
                w.u64(*round);
                w.u64(*shard);
                w.u8(side.to_u8());
                KIND_HALO_ACK
            }
        };
        (kind, w.buf)
    }

    /// Decode from a frame's (kind, payload).
    pub fn decode(kind: u16, payload: &[u8]) -> anyhow::Result<Msg> {
        let mut r = WireReader::new(payload);
        let msg = match kind {
            KIND_PING => Msg::Ping,
            KIND_PONG => {
                let workers = r.u64()? as usize;
                let engine: Engine = r.str()?.parse()?;
                let chunks_served = r.u64()?;
                Msg::Pong(NodeStatus { workers, engine, chunks_served })
            }
            KIND_EVOLVE_CHUNK => {
                let id = r.u64()?;
                let spec = decode_spec(&mut r)?;
                let method: KernelMethod = r.str()?.parse()?;
                let engine: Engine = r.str()?.parse()?;
                let steps = r.u64()? as usize;
                let local_shards = r.u64()? as usize;
                let tile = r.grid()?;
                Msg::EvolveChunk(ChunkRequest {
                    id,
                    spec,
                    method,
                    engine,
                    steps,
                    local_shards,
                    tile,
                })
            }
            KIND_CHUNK_OK => {
                let id = r.u64()?;
                let tile = r.grid()?;
                Msg::ChunkOk(ChunkReply { id, tile })
            }
            KIND_CHUNK_ERR => {
                let id = r.u64()?;
                let error = r.str()?;
                Msg::ChunkErr { id, error }
            }
            KIND_SHUTDOWN => Msg::Shutdown,
            KIND_SHUTDOWN_ACK => Msg::ShutdownAck,
            KIND_EVOLVE_PLAN => {
                let plan = decode_plan(&mut r)?;
                let tiles = decode_tiles(&mut r)?;
                Msg::EvolvePlan(PlanRequest { plan, tiles })
            }
            KIND_PLAN_READY => Msg::PlanReady { epoch: r.u64()? },
            KIND_PLAN_START => Msg::PlanStart { epoch: r.u64()? },
            KIND_PLAN_DONE => {
                let epoch = r.u64()?;
                let tiles = decode_tiles(&mut r)?;
                let stats = decode_stats(&mut r)?;
                Msg::PlanDone(PlanDoneMsg { epoch, tiles, stats })
            }
            KIND_PLAN_ERR => {
                let epoch = r.u64()?;
                let error = r.str()?;
                Msg::PlanErr { epoch, error }
            }
            KIND_HALO_PUSH => {
                let epoch = r.u64()?;
                let round = r.u64()?;
                let shard = r.u64()?;
                let side = BandSide::from_u8(r.u8()?)?;
                let data = decode_f64s(&mut r)?;
                Msg::HaloPush(HaloBand { epoch, round, shard, side, data })
            }
            KIND_HALO_ACK => {
                let epoch = r.u64()?;
                let round = r.u64()?;
                let shard = r.u64()?;
                let side = BandSide::from_u8(r.u8()?)?;
                Msg::HaloAck { epoch, round, shard, side }
            }
            other => anyhow::bail!("unknown message kind {other}"),
        };
        r.finish()?;
        Ok(msg)
    }
}

/// Send one message as a frame.
pub fn send_msg(w: &mut impl Write, msg: &Msg) -> anyhow::Result<usize> {
    let (kind, payload) = msg.encode();
    let n = frame::HEADER_LEN + payload.len();
    frame::send_frame(w, kind, &payload)?;
    Ok(n)
}

/// Outcome of one [`recv_msg`] poll (mirrors [`frame::Recv`]).
#[derive(Debug, Clone, PartialEq)]
pub enum MsgRecv {
    /// A decoded message and its total wire size in bytes.
    Msg(Msg, usize),
    /// Peer closed at a frame boundary.
    Eof,
    /// No bytes before the stream's read timeout.
    Idle,
}

/// Receive and decode one message (see [`frame::recv_frame`] for the
/// deadline/idle semantics).
pub fn recv_msg(r: &mut impl Read, deadline: Duration) -> anyhow::Result<MsgRecv> {
    Ok(match frame::recv_frame(r, deadline)? {
        frame::Recv::Frame(kind, payload) => {
            let n = frame::HEADER_LEN + payload.len();
            MsgRecv::Msg(Msg::decode(kind, &payload)?, n)
        }
        frame::Recv::Eof => MsgRecv::Eof,
        frame::Recv::Idle => MsgRecv::Idle,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) -> Msg {
        let (kind, payload) = msg.encode();
        Msg::decode(kind, &payload).unwrap()
    }

    #[test]
    fn every_message_roundtrips() {
        let tile = DenseGrid::verification_input(&[6, 5], 42);
        let msgs = [
            Msg::Ping,
            Msg::Pong(NodeStatus { workers: 4, engine: Engine::Simd, chunks_served: 17 }),
            Msg::EvolveChunk(ChunkRequest {
                id: 9,
                spec: StencilSpec::star2d(2),
                method: KernelMethod::Outer,
                engine: Engine::Compiled,
                steps: 3,
                local_shards: 2,
                tile: tile.clone(),
            }),
            Msg::ChunkOk(ChunkReply { id: 9, tile }),
            Msg::ChunkErr { id: 3, error: "tile too small".to_string() },
            Msg::Shutdown,
            Msg::ShutdownAck,
        ];
        for msg in &msgs {
            assert_eq!(&roundtrip(msg), msg);
        }
    }

    #[test]
    fn peer_messages_roundtrip() {
        let tile = DenseGrid::verification_input(&[8, 5], 3);
        let plan = ExchangePlan {
            epoch: 0xDEAD_BEEF,
            spec: StencilSpec::box2d(2),
            method: KernelMethod::Taps,
            engine: Engine::Compiled,
            steps: 12,
            fuse: 3,
            local_shards: 2,
            band_timeout_ms: 10_000,
            part: Partition::new(&[24, 5], 3, 6).unwrap(),
            owners: vec![0, 1, 0],
            peers: vec!["127.0.0.1:9001".to_string(), "127.0.0.1:9002".to_string()],
            self_node: 1,
        };
        let msgs = [
            Msg::EvolvePlan(PlanRequest {
                plan: plan.clone(),
                tiles: vec![(0, tile.clone()), (2, tile.clone())],
            }),
            Msg::PlanReady { epoch: 7 },
            Msg::PlanStart { epoch: 7 },
            Msg::PlanDone(PlanDoneMsg {
                epoch: 7,
                tiles: vec![(1, tile)],
                stats: PlanStats {
                    rounds: 4,
                    bands_sent: 8,
                    band_bytes_sent: 4096,
                    band_bytes_recv: 4096,
                    exchange_hidden_seconds: 0.25,
                    exchange_visible_seconds: 0.01,
                    compute_seconds: 0.5,
                },
            }),
            Msg::PlanErr { epoch: 7, error: "band timeout".to_string() },
            Msg::HaloPush(HaloBand {
                epoch: 7,
                round: 2,
                shard: 1,
                side: BandSide::FromUpper,
                data: vec![1.5, -0.0, f64::MIN_POSITIVE, 3.25],
            }),
            Msg::HaloAck { epoch: 7, round: 2, shard: 1, side: BandSide::FromLower },
        ];
        for msg in &msgs {
            assert_eq!(&roundtrip(msg), msg);
        }
    }

    #[test]
    fn plan_decode_rejects_inconsistent_payloads() {
        // self_node out of range
        let plan = ExchangePlan {
            epoch: 1,
            spec: StencilSpec::box2d(1),
            method: KernelMethod::Taps,
            engine: Engine::Compiled,
            steps: 2,
            fuse: 1,
            local_shards: 0,
            band_timeout_ms: 1000,
            part: Partition::new(&[8, 4], 2, 1).unwrap(),
            owners: vec![0, 0],
            peers: vec!["127.0.0.1:1".to_string()],
            self_node: 5,
        };
        let (kind, payload) =
            Msg::EvolvePlan(PlanRequest { plan: plan.clone(), tiles: vec![] }).encode();
        let err = Msg::decode(kind, &payload).unwrap_err().to_string();
        assert!(err.contains("self_node"), "{err}");

        // owner index out of range
        let mut bad = plan.clone();
        bad.owners = vec![0, 3];
        bad.self_node = 0;
        let (kind, payload) = Msg::EvolvePlan(PlanRequest { plan: bad, tiles: vec![] }).encode();
        let err = Msg::decode(kind, &payload).unwrap_err().to_string();
        assert!(err.contains("owner index"), "{err}");

        // forged giant band length must refuse before allocating
        let mut w = WireWriter::new();
        w.u64(1); // epoch
        w.u64(0); // round
        w.u64(0); // shard
        w.u8(0); // side
        w.u64(u64::MAX / 2); // band length
        let err = Msg::decode(KIND_HALO_PUSH, &w.buf).unwrap_err().to_string();
        assert!(err.contains("larger than a frame"), "{err}");
    }

    #[test]
    fn grid_payloads_are_bitwise_exact() {
        // values that decimal round-trips would mangle: subnormals,
        // negative zero, and full-precision irrationals
        let g = DenseGrid {
            shape: vec![2, 3],
            data: vec![f64::MIN_POSITIVE / 2.0, -0.0, std::f64::consts::PI, 1e-300, -3.5, 0.1],
        };
        let mut w = WireWriter::new();
        w.grid(&g);
        let mut r = WireReader::new(&w.buf);
        let back = r.grid().unwrap();
        r.finish().unwrap();
        assert_eq!(back.shape, g.shape);
        for (a, b) in back.data.iter().zip(&g.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Msg::decode(999, &[]).is_err());
        // trailing garbage after a valid Ping payload
        assert!(Msg::decode(KIND_PING, &[0xFF]).is_err());
        // truncated chunk payload
        let (kind, payload) = Msg::ChunkOk(ChunkReply {
            id: 1,
            tile: DenseGrid::verification_input(&[4, 4], 1),
        })
        .encode();
        assert!(Msg::decode(kind, &payload[..payload.len() - 5]).is_err());
        // forged giant shape must refuse before allocating
        let mut w = WireWriter::new();
        w.u64(1); // id
        w.u8(2);
        w.u64(u32::MAX as u64);
        w.u64(u32::MAX as u64);
        assert!(Msg::decode(KIND_CHUNK_OK, &w.buf).is_err());
    }
}
