//! Distributed serving: a fleet of worker **nodes** (OS processes)
//! driven by one **coordinator**, std-only over TCP.
//!
//! This scales PR 5's in-process deep-halo machinery across processes.
//! The pieces, bottom-up:
//!
//! - [`frame`] — length-prefixed binary framing with a versioned header
//!   (magic `STCF`, version, kind, length). The decoder rejects bad
//!   magic, wrong versions, oversized lengths, and truncated/stalled
//!   frames with clean errors instead of blocking.
//! - [`proto`] — the seven protocol messages and their codec. Grid data
//!   travels as f64 **bit patterns**, so the wire never costs a ulp.
//! - [`node`] — a worker: accept loop + the existing
//!   [`ShardedEvolver`](crate::serve::ShardedEvolver) doing the actual
//!   stencil math.
//! - [`coordinator`] — slab placement, fused T-step rounds,
//!   coordinator-mediated `order·T`-deep halo exchange once per T
//!   steps, node health checks, and re-placement on node loss.
//!
//! **The contract:** a fleet evolution is bitwise identical to the
//! single-process sharded evolver (and therefore, for the oracle/taps
//! kernels, to the scalar oracle). The coordinator reuses the very
//! same [`Partition`](crate::serve::Partition) / halo-exchange /
//! assembly code the in-process path runs; nodes reuse the very same
//! evolver. Nothing is approximated in transit.
//!
//! Observability: `stencil_cluster_*` metric families (per-node chunk
//! counters, liveness gauges, replacement counter, byte counters, an
//! RPC latency histogram) plus `cluster.round` / `cluster.rpc` /
//! `cluster.exchange` spans — see the taxonomy in [`crate::obs`].

pub mod coordinator;
pub mod frame;
pub mod node;
pub mod proto;

pub use coordinator::{ClusterReport, Coordinator, DEFAULT_RPC_TIMEOUT};
pub use node::{spawn_local, NodeConfig, NodeHandle};
pub use proto::{Msg, NodeStatus};
