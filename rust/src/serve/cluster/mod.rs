//! Distributed serving: a fleet of worker **nodes** (OS processes)
//! driven by one **coordinator**, std-only over TCP.
//!
//! This scales PR 5's in-process deep-halo machinery across processes.
//! The pieces, bottom-up:
//!
//! - [`frame`] — length-prefixed binary framing with a versioned header
//!   (magic `STCF`, version, kind, length). The decoder rejects bad
//!   magic, wrong versions, oversized lengths, and truncated/stalled
//!   frames with clean errors instead of blocking.
//! - [`proto`] — the protocol messages and their codec: the mediated
//!   chunk RPCs (kinds 1–7, protocol v1) plus the peer-exchange plan
//!   handshake and `HaloPush`/`HaloAck` band frames (kinds 8–14,
//!   protocol v2). Grid data travels as f64 **bit patterns**, so the
//!   wire never costs a ulp.
//! - [`node`] — a worker: accept loop + the existing
//!   [`ShardedEvolver`](crate::serve::ShardedEvolver) doing the actual
//!   stencil math.
//! - [`peer`] — node-side peer-to-peer halo exchange: band staging,
//!   outbound peer links with an ack barrier, and the overlapped
//!   interior/boundary round loop.
//! - [`coordinator`] — slab placement, node health checks, and the two
//!   data paths: **peer** (distribute one exchange plan, nodes trade
//!   `order·T`-deep bands directly, overlapped with compute) and
//!   **mediated** (tiles round-trip through the coordinator each fused
//!   round; also the automatic fallback when a peer plan fails).
//!
//! **The contract:** a fleet evolution is bitwise identical to the
//! single-process sharded evolver (and therefore, for the oracle/taps
//! kernels, to the scalar oracle) — on *either* data path. The
//! coordinator reuses the very same
//! [`Partition`](crate::serve::Partition) / halo-exchange / assembly
//! code the in-process path runs; nodes reuse the very same evolver;
//! peer bands carry exactly the rows the serial exchange would copy.
//! Nothing is approximated in transit.
//!
//! Observability: `stencil_cluster_*` metric families (per-node chunk
//! counters, liveness gauges, replacement counter, byte counters, an
//! RPC latency histogram, per-path exchange histograms and wire-byte
//! counters, an overlap-ratio gauge, a peer-fallback counter) plus
//! `cluster.round` / `cluster.rpc` / `cluster.exchange` /
//! `cluster.peer_exchange` spans — see the taxonomy in [`crate::obs`].

pub mod coordinator;
pub mod frame;
pub mod node;
pub mod peer;
pub mod proto;

pub use coordinator::{ClusterReport, Coordinator, ExchangeMode, DEFAULT_RPC_TIMEOUT};
pub use node::{spawn_local, NodeConfig, NodeHandle};
pub use proto::{Msg, NodeStatus};
