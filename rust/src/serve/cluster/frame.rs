//! Length-prefixed binary framing for the cluster protocol (std-only).
//!
//! Every message on a cluster connection is one *frame*:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"STCF"
//! 4       2     protocol version, little-endian (currently 2)
//! 6       2     message kind, little-endian (see `proto::Msg`)
//! 8       4     payload length in bytes, little-endian
//! 12      len   payload
//! ```
//!
//! The decoder is defensive by contract, not by luck:
//!
//! - **Bad magic / wrong version / oversized length** are rejected with a
//!   clean error as soon as the 12-byte header is in — the payload is
//!   never read, so a peer speaking a future protocol (or not speaking
//!   this protocol at all) cannot make the reader allocate or block.
//! - **Truncated frames** (peer closed, or stalled mid-frame past the
//!   read deadline) produce a clean error instead of blocking forever:
//!   the caller sets a short OS read timeout on the stream, and
//!   [`recv_frame`] converts "partial frame + deadline exceeded" into an
//!   error while "no bytes at a frame boundary" stays a benign
//!   [`Recv::Idle`] (so accept loops can poll a stop flag between
//!   frames).

use std::io::{ErrorKind, Read, Write};
use std::time::{Duration, Instant};

/// Frame magic: every frame starts with these four bytes.
pub const MAGIC: [u8; 4] = *b"STCF";
/// Protocol version carried in (and required of) every frame header.
/// History: 1 = PR 9 coordinator-mediated protocol (kinds 1–7);
/// 2 = peer-to-peer halo exchange (kinds 8–14: exchange plans and
/// `HaloPush`/`HaloAck` band frames).
pub const VERSION: u16 = 2;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 12;
/// Hard cap on payload length. Large enough for any grid this repo
/// serves (a 2048³ f64 grid is 64 GiB and is *not* a cluster tile;
/// tiles are slabs of much smaller serving grids), small enough that a
/// corrupt or hostile length field cannot drive an allocation.
pub const MAX_FRAME_LEN: usize = 256 * 1024 * 1024;

/// A peer spoke a different protocol version. Typed (rather than a
/// plain message) so the coordinator's connect handshake can surface a
/// version skew as its own clear error instead of a generic
/// dead-node/decode failure — see `Coordinator::connect`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VersionMismatch {
    /// The version the peer's frame header carried.
    pub theirs: u16,
}

impl std::fmt::Display for VersionMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unsupported protocol version {} (this build speaks version {VERSION}); \
             coordinator and nodes must run the same build",
            self.theirs
        )
    }
}

impl std::error::Error for VersionMismatch {}

/// A validated frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    /// Message kind (dispatched by `proto::Msg::decode`).
    pub kind: u16,
    /// Payload length in bytes (already checked against
    /// [`MAX_FRAME_LEN`]).
    pub len: u32,
}

/// Encode a frame header. Fails if `len` exceeds [`MAX_FRAME_LEN`] —
/// the sender enforces the same cap the receiver does.
pub fn encode_header(kind: u16, len: usize) -> anyhow::Result<[u8; HEADER_LEN]> {
    anyhow::ensure!(
        len <= MAX_FRAME_LEN,
        "frame payload of {len} byte(s) exceeds the {MAX_FRAME_LEN}-byte frame cap"
    );
    let mut h = [0u8; HEADER_LEN];
    h[0..4].copy_from_slice(&MAGIC);
    h[4..6].copy_from_slice(&VERSION.to_le_bytes());
    h[6..8].copy_from_slice(&kind.to_le_bytes());
    h[8..12].copy_from_slice(&(len as u32).to_le_bytes());
    Ok(h)
}

/// Decode and validate a frame header: magic, version, and length cap.
pub fn decode_header(h: &[u8; HEADER_LEN]) -> anyhow::Result<FrameHeader> {
    anyhow::ensure!(
        h[0..4] == MAGIC,
        "bad frame magic {:02x?} (expected {:02x?}: not a cluster-protocol peer?)",
        &h[0..4],
        MAGIC
    );
    let version = u16::from_le_bytes([h[4], h[5]]);
    if version != VERSION {
        return Err(anyhow::Error::new(VersionMismatch { theirs: version }));
    }
    let kind = u16::from_le_bytes([h[6], h[7]]);
    let len = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
    anyhow::ensure!(
        (len as usize) <= MAX_FRAME_LEN,
        "oversized frame: {len} byte(s) exceeds the {MAX_FRAME_LEN}-byte frame cap"
    );
    Ok(FrameHeader { kind, len })
}

/// Write one frame (header + payload).
pub fn send_frame(w: &mut impl Write, kind: u16, payload: &[u8]) -> anyhow::Result<()> {
    let header = encode_header(kind, payload.len())?;
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Outcome of one [`recv_frame`] poll.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Recv {
    /// A complete, validated frame: (kind, payload).
    Frame(u16, Vec<u8>),
    /// The peer closed the connection cleanly at a frame boundary.
    Eof,
    /// No bytes arrived before the stream's read timeout while at a
    /// frame boundary — not an error; poll a stop flag and call again.
    Idle,
}

/// Read one frame from `r`, which should carry a short OS read timeout
/// (e.g. [`std::net::TcpStream::set_read_timeout`]) so reads surface
/// `WouldBlock`/`TimedOut` instead of blocking indefinitely.
///
/// Semantics:
/// - zero bytes buffered + timeout → [`Recv::Idle`] (benign);
/// - clean close at a frame boundary → [`Recv::Eof`];
/// - close or stall (past `deadline`) *inside* a frame → error
///   ("truncated frame" / "read deadline exceeded");
/// - bad magic, wrong version, oversized length → error before any
///   payload byte is read.
pub fn recv_frame(r: &mut impl Read, deadline: Duration) -> anyhow::Result<Recv> {
    let start = Instant::now();
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(Recv::Eof);
                }
                anyhow::bail!(
                    "truncated frame: peer closed after {got} of {HEADER_LEN} header byte(s)"
                );
            }
            Ok(n) => got += n,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted =>
            {
                if got == 0 {
                    return Ok(Recv::Idle);
                }
                anyhow::ensure!(
                    start.elapsed() < deadline,
                    "read deadline exceeded mid-frame: got {got} of {HEADER_LEN} header byte(s) \
                     in {deadline:?}"
                );
            }
            Err(e) => return Err(anyhow::anyhow!("frame header read failed: {e}")),
        }
    }
    let h = decode_header(&header)?;
    let mut payload = vec![0u8; h.len as usize];
    let mut got = 0usize;
    while got < payload.len() {
        match r.read(&mut payload[got..]) {
            Ok(0) => anyhow::bail!(
                "truncated frame: peer closed after {got} of {} payload byte(s)",
                payload.len()
            ),
            Ok(n) => got += n,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted =>
            {
                anyhow::ensure!(
                    start.elapsed() < deadline,
                    "read deadline exceeded mid-frame: got {got} of {} payload byte(s) in \
                     {deadline:?}",
                    payload.len()
                );
            }
            Err(e) => return Err(anyhow::anyhow!("frame payload read failed: {e}")),
        }
    }
    Ok(Recv::Frame(h.kind, payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn header_roundtrip() {
        let h = encode_header(7, 4096).unwrap();
        assert_eq!(decode_header(&h).unwrap(), FrameHeader { kind: 7, len: 4096 });
        assert_eq!(encode_header(0, 0).map(|h| decode_header(&h).unwrap().len), Ok(0));
    }

    #[test]
    fn frame_roundtrip_over_a_buffer() {
        let mut buf = Vec::new();
        send_frame(&mut buf, 3, b"hello cluster").unwrap();
        let mut cur = Cursor::new(buf);
        match recv_frame(&mut cur, Duration::from_secs(1)).unwrap() {
            Recv::Frame(kind, payload) => {
                assert_eq!(kind, 3);
                assert_eq!(payload, b"hello cluster");
            }
            other => panic!("expected a frame, got {other:?}"),
        }
        // the stream is now at a clean frame boundary: EOF, not an error
        assert_eq!(recv_frame(&mut cur, Duration::from_secs(1)).unwrap(), Recv::Eof);
    }

    #[test]
    fn bad_magic_version_and_oversize_are_clean_errors() {
        let mut h = encode_header(1, 8).unwrap();
        h[0] = b'X';
        let err = decode_header(&h).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        let mut h = encode_header(1, 8).unwrap();
        h[4..6].copy_from_slice(&99u16.to_le_bytes());
        let err = decode_header(&h).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");

        let mut h = encode_header(1, 8).unwrap();
        h[8..12].copy_from_slice(&(u32::MAX).to_le_bytes());
        let err = decode_header(&h).unwrap_err().to_string();
        assert!(err.contains("oversized"), "{err}");

        assert!(encode_header(1, MAX_FRAME_LEN + 1).is_err());
    }

    #[test]
    fn version_mismatch_is_a_typed_error() {
        // a version-1 (PR 9) peer against this version-2 build: the
        // error is downcastable so handshakes can tell skew from noise,
        // and the message says what to do about it
        let mut h = encode_header(1, 8).unwrap();
        h[4..6].copy_from_slice(&1u16.to_le_bytes());
        let err = decode_header(&h).unwrap_err();
        let vm = err.downcast_ref::<VersionMismatch>().expect("typed version error");
        assert_eq!(vm.theirs, 1);
        assert!(err.to_string().contains("unsupported protocol version 1"), "{err}");
        assert!(err.to_string().contains("must run the same build"), "{err}");
    }

    #[test]
    fn truncated_frames_error_instead_of_blocking() {
        // header cut short
        let mut buf = Vec::new();
        send_frame(&mut buf, 2, b"payload").unwrap();
        let mut cur = Cursor::new(buf[..HEADER_LEN - 3].to_vec());
        let err = recv_frame(&mut cur, Duration::from_secs(1)).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");

        // payload cut short
        let mut buf = Vec::new();
        send_frame(&mut buf, 2, b"payload").unwrap();
        let mut cur = Cursor::new(buf[..HEADER_LEN + 3].to_vec());
        let err = recv_frame(&mut cur, Duration::from_secs(1)).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }
}
