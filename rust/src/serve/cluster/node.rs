//! A cluster worker node: a TCP server that evolves slab tiles with the
//! in-process [`ShardedEvolver`] and speaks the framed cluster protocol.
//!
//! The node is deliberately thin — all stencil correctness lives in the
//! evolver it wraps. One accept loop (non-blocking listener polling a
//! stop flag, exactly like `obs::live`) hands each connection to its own
//! thread; a connection is a sequence of frames handled strictly in
//! order, so a coordinator that pipelines several `EvolveChunk` requests
//! on one connection gets replies in request order.
//!
//! **Bitwise contract.** For a chunk request the node runs
//! `evolve_fused(spec, tile, steps, local_shards, method, fuse = steps)`
//! on the tile. By the scheduler's invariants (fused == unfused ==
//! reference bitwise for oracle/taps; sharded == single-shard bitwise
//! for the KIR host kernels; fused plan == repeated single applications
//! bitwise) the reply is bitwise identical to applying one
//! `steps`-deep fused plan to the tile on the coordinator's own thread —
//! whatever local shard count the node picks. Degenerate tiles (any
//! dim ≤ 2·order) are identity copies, mirroring
//! [`crate::serve::CompiledPlan::apply`].

use super::peer;
use super::proto::{self, ChunkReply, Msg, MsgRecv, NodeStatus, PlanDoneMsg, PlanRequest};
use crate::kir::Engine;
use crate::obs::registry;
use crate::serve::scheduler::ShardedEvolver;
use crate::serve::{PlanCache, WorkerPool};
use crate::stencil::DenseGrid;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How a node is provisioned.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    /// Worker threads in the node's pool (0 = one per core).
    pub workers: usize,
    /// Default local shard count per tile when a request leaves the
    /// choice to the node (0 = one per worker). Results are bitwise
    /// independent of this value.
    pub shards: usize,
    /// Host engine for KIR shard kernels.
    pub engine: Engine,
    /// Fault injection for tests and smoke runs: after serving this many
    /// chunks (mediated path) or fused rounds of a peer plan (peer
    /// path), the node drops the connection without replying and stops
    /// accepting — simulating a node lost mid-evolution.
    pub fail_after: Option<usize>,
}

impl Default for NodeConfig {
    fn default() -> NodeConfig {
        NodeConfig { workers: 0, shards: 0, engine: Engine::default(), fail_after: None }
    }
}

struct NodeState {
    evolver: ShardedEvolver,
    cfg: NodeConfig,
    stop: Arc<AtomicBool>,
    chunks_served: AtomicU64,
    requests_total: registry::Counter,
    chunks_total: registry::Counter,
}

/// Handle to a running node; stops on [`NodeHandle::shutdown`] or drop.
pub struct NodeHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl NodeHandle {
    /// The address actually bound (resolves an ephemeral `:0` port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// True until [`NodeHandle::shutdown`] (or a `fail_after` trip)
    /// stopped the accept loop.
    pub fn is_running(&self) -> bool {
        !self.stop.load(Ordering::SeqCst)
    }

    /// Stop accepting and join the accept thread. Idempotent; also runs
    /// on drop. In-flight connections notice the flag at their next
    /// frame boundary.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block until the accept loop exits (external shutdown, a
    /// `Shutdown` frame, or a `fail_after` trip).
    pub fn join(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NodeHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Bind `addr` (e.g. `127.0.0.1:7401`, port `0` for ephemeral) and serve
/// the cluster protocol until shutdown.
pub fn serve(addr: &str, cfg: NodeConfig) -> anyhow::Result<NodeHandle> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("cannot bind cluster node on {addr}: {e}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let workers = if cfg.workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2)
    } else {
        cfg.workers
    };
    let mut cache = PlanCache::new(32);
    cache.set_engine(cfg.engine);
    let r = registry::global();
    let state = Arc::new(NodeState {
        evolver: ShardedEvolver::with_parts(Arc::new(WorkerPool::new(workers)), Arc::new(cache)),
        cfg,
        stop: Arc::clone(&stop),
        chunks_served: AtomicU64::new(0),
        requests_total: r.counter("stencil_cluster_node_requests_total"),
        chunks_total: r.counter("stencil_cluster_node_chunks_total"),
    });
    let stop_accept = Arc::clone(&stop);
    let accept = std::thread::Builder::new()
        .name("stencil-cluster-node".to_string())
        .spawn(move || {
            while !stop_accept.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let state = Arc::clone(&state);
                        let _ = std::thread::Builder::new()
                            .name("stencil-cluster-conn".to_string())
                            .spawn(move || handle_conn(stream, &state));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(5)),
                }
            }
        })
        .map_err(|e| anyhow::anyhow!("failed to spawn cluster accept thread: {e}"))?;
    Ok(NodeHandle { addr: local, stop, accept: Some(accept) })
}

/// Spawn an in-process node on a loopback ephemeral port — what
/// `cluster-bench` and the subsystem tests use: real sockets, real
/// frames, no extra OS processes to babysit.
pub fn spawn_local(cfg: NodeConfig) -> anyhow::Result<NodeHandle> {
    serve("127.0.0.1:0", cfg)
}

fn handle_conn(mut stream: TcpStream, state: &NodeState) {
    // short read timeout: recv turns it into Idle so the loop can poll
    // the stop flag; a peer stalled mid-frame errors out at the deadline
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_nodelay(true);
    let frame_deadline = Duration::from_secs(10);
    // an exchange plan parks here between EvolvePlan and PlanStart; the
    // staging guard keeps band staging registered (and deregisters it if
    // the connection dies before the plan runs)
    let mut pending: Option<(PlanRequest, peer::StagingGuard)> = None;
    loop {
        if state.stop.load(Ordering::SeqCst) {
            return;
        }
        let (msg, wire) = match proto::recv_msg(&mut stream, frame_deadline) {
            Ok(MsgRecv::Msg(msg, n)) => (msg, n),
            Ok(MsgRecv::Idle) => continue,
            Ok(MsgRecv::Eof) | Err(_) => return,
        };
        state.requests_total.inc();
        match msg {
            Msg::Ping => {
                let status = NodeStatus {
                    workers: state.evolver.pool().workers(),
                    engine: state.evolver.cache().engine(),
                    chunks_served: state.chunks_served.load(Ordering::Relaxed),
                };
                if proto::send_msg(&mut stream, &Msg::Pong(status)).is_err() {
                    return;
                }
            }
            Msg::EvolveChunk(req) => {
                // fault injection: past the trip point the node goes
                // silent and stops accepting, like a process that died
                if let Some(limit) = state.cfg.fail_after {
                    if state.chunks_served.load(Ordering::Relaxed) >= limit as u64 {
                        state.stop.store(true, Ordering::SeqCst);
                        return;
                    }
                }
                let id = req.id;
                let reply = match evolve_tile(state, req) {
                    Ok(tile) => {
                        state.chunks_served.fetch_add(1, Ordering::Relaxed);
                        state.chunks_total.inc();
                        Msg::ChunkOk(ChunkReply { id, tile })
                    }
                    Err(e) => Msg::ChunkErr { id, error: format!("{e:#}") },
                };
                if proto::send_msg(&mut stream, &reply).is_err() {
                    return;
                }
            }
            Msg::Shutdown => {
                let _ = proto::send_msg(&mut stream, &Msg::ShutdownAck);
                state.stop.store(true, Ordering::SeqCst);
                return;
            }
            Msg::EvolvePlan(req) => {
                let epoch = req.plan.epoch;
                if req.plan.engine != state.evolver.cache().engine() {
                    let err = Msg::PlanErr {
                        epoch,
                        error: format!(
                            "engine mismatch: plan wants {}, node compiles {}",
                            req.plan.engine,
                            state.evolver.cache().engine()
                        ),
                    };
                    if proto::send_msg(&mut stream, &err).is_err() {
                        return;
                    }
                    continue;
                }
                // register staging *before* PlanReady goes out, so no
                // peer's band can beat the registration
                let guard = peer::register(epoch);
                pending = Some((req, guard));
                if proto::send_msg(&mut stream, &Msg::PlanReady { epoch }).is_err() {
                    return;
                }
            }
            Msg::PlanStart { epoch } => {
                // PlanStart without a matching parked plan is a protocol
                // violation — drop the connection
                let Some((req, guard)) = pending.take() else { return };
                if req.plan.epoch != epoch {
                    return;
                }
                let shards = match (req.plan.local_shards, state.cfg.shards) {
                    (0, 0) => state.evolver.pool().workers(),
                    (0, s) => s,
                    (s, _) => s,
                };
                let result = peer::run_plan(
                    &state.evolver,
                    shards,
                    &req,
                    guard.staging(),
                    &state.stop,
                    state.cfg.fail_after,
                );
                drop(guard);
                match result {
                    Ok((tiles, stats)) => {
                        let evolved = tiles.len() as u64 * stats.rounds;
                        state.chunks_served.fetch_add(evolved, Ordering::Relaxed);
                        state.chunks_total.add(evolved);
                        let done = Msg::PlanDone(PlanDoneMsg { epoch, tiles, stats });
                        if proto::send_msg(&mut stream, &done).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        if state.stop.load(Ordering::SeqCst) {
                            // killed (shutdown or fault injection): go
                            // silent like a dead process — the
                            // coordinator sees EOF, not a clean error
                            return;
                        }
                        let err = Msg::PlanErr { epoch, error: format!("{e:#}") };
                        if proto::send_msg(&mut stream, &err).is_err() {
                            return;
                        }
                    }
                }
            }
            Msg::HaloPush(band) => {
                let ack = Msg::HaloAck {
                    epoch: band.epoch,
                    round: band.round,
                    shard: band.shard,
                    side: band.side,
                };
                // bands for unknown epochs (stale or failed plans) are
                // dropped; the sender's plan fails via band timeouts
                peer::deposit(band, wire as u64);
                if proto::send_msg(&mut stream, &ack).is_err() {
                    return;
                }
            }
            // node-bound protocol only; a peer sending coordinator-bound
            // (or ack-channel) messages is confused — drop it
            Msg::Pong(_)
            | Msg::ChunkOk(_)
            | Msg::ChunkErr { .. }
            | Msg::ShutdownAck
            | Msg::PlanReady { .. }
            | Msg::PlanDone(_)
            | Msg::PlanErr { .. }
            | Msg::HaloAck { .. } => return,
        }
    }
}

/// Evolve one tile. Degenerate tiles (any dim ≤ 2·order) are identity
/// copies, exactly like [`crate::serve::CompiledPlan::apply`] — the
/// evolver itself rejects them as whole grids.
fn evolve_tile(state: &NodeState, req: proto::ChunkRequest) -> anyhow::Result<DenseGrid> {
    let r = req.spec.order;
    if req.tile.shape.iter().any(|&n| n <= 2 * r) {
        return Ok(req.tile);
    }
    anyhow::ensure!(
        req.engine == state.evolver.cache().engine(),
        "engine mismatch: request wants {}, node compiles {}",
        req.engine,
        state.evolver.cache().engine()
    );
    let shards = match (req.local_shards, state.cfg.shards) {
        (0, 0) => state.evolver.pool().workers(),
        (0, s) => s,
        (s, _) => s,
    };
    let (out, _, _) = state.evolver.evolve_fused(
        req.spec,
        &req.tile,
        req.steps,
        shards,
        req.method,
        req.steps.max(1),
    )?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::KernelMethod;
    use crate::stencil::{reference, CoeffTensor, DenseGrid, StencilSpec};

    fn connect(addr: SocketAddr) -> TcpStream {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        s
    }

    fn rpc(stream: &mut TcpStream, msg: &Msg) -> Msg {
        proto::send_msg(stream, msg).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        loop {
            match proto::recv_msg(stream, Duration::from_secs(30)).unwrap() {
                MsgRecv::Msg(m, _) => return m,
                MsgRecv::Idle => assert!(std::time::Instant::now() < deadline, "rpc timed out"),
                MsgRecv::Eof => panic!("node closed the connection"),
            }
        }
    }

    #[test]
    fn node_answers_ping_and_evolves_a_tile_bitwise() {
        let mut node =
            spawn_local(NodeConfig { workers: 2, ..NodeConfig::default() }).unwrap();
        let mut stream = connect(node.addr());

        match rpc(&mut stream, &Msg::Ping) {
            Msg::Pong(st) => assert_eq!(st.workers, 2),
            other => panic!("expected Pong, got {other:?}"),
        }

        let spec = StencilSpec::box2d(1);
        let tile = DenseGrid::verification_input(&[12, 10], 7);
        let req = proto::ChunkRequest {
            id: 5,
            spec,
            method: KernelMethod::Taps,
            engine: Engine::default(),
            steps: 2,
            local_shards: 0,
            tile: tile.clone(),
        };
        let reply = rpc(&mut stream, &Msg::EvolveChunk(req));
        let coeffs = CoeffTensor::paper_default(spec);
        let want = reference::apply(&coeffs, &reference::apply(&coeffs, &tile));
        match reply {
            Msg::ChunkOk(rep) => {
                assert_eq!(rep.id, 5);
                assert_eq!(rep.tile, want, "node tile evolution diverged from the oracle");
            }
            other => panic!("expected ChunkOk, got {other:?}"),
        }

        // degenerate tile: identity, not an error
        let tiny = DenseGrid::verification_input(&[2, 9], 1);
        let req = proto::ChunkRequest {
            id: 6,
            spec,
            method: KernelMethod::Taps,
            engine: Engine::default(),
            steps: 3,
            local_shards: 0,
            tile: tiny.clone(),
        };
        match rpc(&mut stream, &Msg::EvolveChunk(req)) {
            Msg::ChunkOk(rep) => assert_eq!(rep.tile, tiny),
            other => panic!("expected ChunkOk, got {other:?}"),
        }

        match rpc(&mut stream, &Msg::Shutdown) {
            Msg::ShutdownAck => {}
            other => panic!("expected ShutdownAck, got {other:?}"),
        }
        node.join();
        assert!(!node.is_running());
    }

    #[test]
    fn engine_mismatch_is_a_chunk_error_not_a_hang() {
        let mut node = spawn_local(NodeConfig {
            workers: 1,
            engine: Engine::Interpret,
            ..NodeConfig::default()
        })
        .unwrap();
        let mut stream = connect(node.addr());
        let req = proto::ChunkRequest {
            id: 1,
            spec: StencilSpec::box2d(1),
            method: KernelMethod::Outer,
            engine: Engine::Compiled,
            steps: 1,
            local_shards: 0,
            tile: DenseGrid::verification_input(&[8, 8], 0),
        };
        match rpc(&mut stream, &Msg::EvolveChunk(req)) {
            Msg::ChunkErr { id, error } => {
                assert_eq!(id, 1);
                assert!(error.contains("engine mismatch"), "{error}");
            }
            other => panic!("expected ChunkErr, got {other:?}"),
        }
        node.shutdown();
    }
}
