//! Node-side peer-to-peer halo exchange with compute/communication
//! overlap — the steady-state data path of the fleet.
//!
//! After the coordinator distributes an exchange plan
//! ([`proto::ExchangePlan`]) and every node acknowledges with
//! `PlanReady` — so band staging is registered before any band can
//! arrive — each node runs every fused round locally:
//!
//! 1. at the end of round `k`, extract the `order·T`-deep boundary
//!    bands from the freshly computed owned rows and enqueue them on the
//!    peer links (or deposit them straight into local staging when the
//!    neighbour shard is co-located) — the link threads put them on the
//!    wire while the node moves on;
//! 2. at round `k + 1`, first compute the slab **interior** (a sub-grid
//!    of exactly the owned rows) while the bands are in flight;
//! 3. then wait for the expected bands, apply them to the ghost rows,
//!    and finish the two boundary regions with small sub-grid evolves.
//!
//! **Bitwise contract.** Every sub-evolve here is the same
//! [`ShardedEvolver::evolve_fused`] call the mediated path makes, and a
//! sub-grid evolution is bitwise identical to the full-tile evolution
//! for every output row whose dependency cone (depth `order·chunk`)
//! avoids the sub-grid's cut edges — rows nearer a cut edge are
//! recomputed by the boundary sub-evolves, whose cones stay inside the
//! fresh ghost rows plus pre-round owned rows. Where a sub-grid edge
//! coincides with a *global* edge the frozen-boundary band coincides
//! too, so validity extends to the edge. The union of the three valid
//! regions is exactly the owned rows, so each round's owned rows equal
//! the mediated path's bitwise; ghost rows are refreshed from the same
//! band contents the serial exchange copies ([`halo::extract_band`] /
//! [`halo::apply_band`] are shared by both paths). Tiles too short for
//! the split (`rows < 2·order·chunk`) fall back to wait-then-evolve —
//! still peer exchange, just no overlap for that shard.

use super::proto::{self, BandSide, HaloBand, Msg, MsgRecv, PlanRequest, PlanStats};
use crate::obs::span::span;
use crate::serve::halo;
use crate::serve::partition::Partition;
use crate::serve::scheduler::ShardedEvolver;
use crate::stencil::DenseGrid;
use std::collections::HashMap;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Band payloads (data, arrival instant, wire bytes) keyed by
/// (round, destination shard, side).
type StagedBands = HashMap<(u64, u64, BandSide), (Vec<f64>, Instant, u64)>;

/// One node's staging area for bands arriving for one plan epoch.
/// Connection threads deposit, the plan runner takes; a [`Condvar`]
/// wakes waiters the moment their band lands.
pub struct BandStaging {
    inner: Mutex<StagedBands>,
    cv: Condvar,
}

impl BandStaging {
    fn new() -> BandStaging {
        BandStaging { inner: Mutex::new(HashMap::new()), cv: Condvar::new() }
    }

    /// Deposit a band (keyed by round, destination shard, and side) with
    /// its wire size; zero bytes for locally deposited bands.
    pub fn deposit(&self, round: u64, shard: u64, side: BandSide, data: Vec<f64>, wire: u64) {
        let mut m = self.inner.lock().unwrap();
        m.insert((round, shard, side), (data, Instant::now(), wire));
        self.cv.notify_all();
    }

    /// Take one band, blocking until it arrives or `deadline` passes.
    /// Returns the band data, its arrival instant, and its wire bytes.
    fn take(
        &self,
        round: u64,
        shard: u64,
        side: BandSide,
        deadline: Instant,
    ) -> anyhow::Result<(Vec<f64>, Instant, u64)> {
        let mut m = self.inner.lock().unwrap();
        loop {
            if let Some(v) = m.remove(&(round, shard, side)) {
                return Ok(v);
            }
            let now = Instant::now();
            anyhow::ensure!(
                now < deadline,
                "timed out waiting for halo band (round {round}, shard {shard}, {side:?}): \
                 peer node lost or stalled"
            );
            let (guard, _) = self.cv.wait_timeout(m, deadline - now).unwrap();
            m = guard;
        }
    }
}

fn staging_registry() -> &'static Mutex<HashMap<u64, Arc<BandStaging>>> {
    static R: OnceLock<Mutex<HashMap<u64, Arc<BandStaging>>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Keeps one epoch's staging registered; deregisters on drop so a
/// failed or finished plan cannot leak staged bands.
pub struct StagingGuard {
    epoch: u64,
    staging: Arc<BandStaging>,
}

impl StagingGuard {
    /// The staging area this guard keeps registered.
    pub fn staging(&self) -> &Arc<BandStaging> {
        &self.staging
    }
}

impl Drop for StagingGuard {
    fn drop(&mut self) {
        staging_registry().lock().unwrap().remove(&self.epoch);
    }
}

/// Register staging for a plan epoch — must happen *before* `PlanReady`
/// is sent, so no peer's band can arrive unregistered.
pub fn register(epoch: u64) -> StagingGuard {
    let staging = Arc::new(BandStaging::new());
    staging_registry().lock().unwrap().insert(epoch, Arc::clone(&staging));
    StagingGuard { epoch, staging }
}

/// Deposit an incoming band into its epoch's staging. Returns false when
/// the epoch is unknown (stale or failed plan) — the band is dropped and
/// failure propagates through the sender's plan via band-wait timeouts.
pub fn deposit(band: HaloBand, wire: u64) -> bool {
    let staging = staging_registry().lock().unwrap().get(&band.epoch).cloned();
    match staging {
        Some(s) => {
            s.deposit(band.round, band.shard, band.side, band.data, wire);
            true
        }
        None => false,
    }
}

/// One outbound connection to a peer node: a sender thread drains an
/// unbounded queue of bands onto the wire (so enqueueing never blocks
/// the compute loop) and counts `HaloAck`s back; at shutdown it holds an
/// ack barrier so the link only reports clean once every pushed band was
/// acknowledged.
struct PeerLink {
    tx: Option<mpsc::Sender<HaloBand>>,
    handle: Option<JoinHandle<(u64, u64)>>,
    error: Arc<Mutex<Option<String>>>,
    addr: String,
}

impl PeerLink {
    fn connect(addr: &str, ack_deadline: Duration) -> anyhow::Result<PeerLink> {
        let sock = addr
            .to_socket_addrs()
            .map_err(|e| anyhow::anyhow!("bad peer address '{addr}': {e}"))?
            .next()
            .ok_or_else(|| anyhow::anyhow!("peer address '{addr}' resolved to nothing"))?;
        let stream = TcpStream::connect_timeout(&sock, Duration::from_secs(5))
            .map_err(|e| anyhow::anyhow!("cannot connect to peer node {addr}: {e}"))?;
        stream.set_read_timeout(Some(Duration::from_millis(5)))?;
        stream.set_write_timeout(Some(Duration::from_secs(30)))?;
        stream.set_nodelay(true)?;
        let error = Arc::new(Mutex::new(None));
        let err = Arc::clone(&error);
        let (tx, rx) = mpsc::channel::<HaloBand>();
        let handle = std::thread::Builder::new()
            .name("stencil-cluster-peer".to_string())
            .spawn(move || link_thread(stream, rx, err, ack_deadline))
            .map_err(|e| anyhow::anyhow!("failed to spawn peer link thread: {e}"))?;
        Ok(PeerLink { tx: Some(tx), handle: Some(handle), error, addr: addr.to_string() })
    }

    fn push(&self, band: HaloBand) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(band);
        }
    }

    fn error(&self) -> Option<String> {
        self.error.lock().unwrap().clone()
    }

    /// Close the queue, wait for the ack barrier, and return
    /// (bands pushed, wire bytes) — or the link's error.
    fn finish(mut self) -> anyhow::Result<(u64, u64)> {
        self.tx = None;
        let handle = self.handle.take().expect("peer link finished twice");
        let counts = handle.join().map_err(|_| anyhow::anyhow!("peer link thread panicked"))?;
        if let Some(e) = self.error.lock().unwrap().clone() {
            anyhow::bail!("peer link to {}: {e}", self.addr);
        }
        Ok(counts)
    }
}

impl Drop for PeerLink {
    fn drop(&mut self) {
        // close the queue; the link thread exits on its own (bounded by
        // the ack deadline), so an erroring plan never blocks here
        self.tx = None;
    }
}

fn link_thread(
    mut stream: TcpStream,
    rx: mpsc::Receiver<HaloBand>,
    error: Arc<Mutex<Option<String>>>,
    ack_deadline: Duration,
) -> (u64, u64) {
    let mut pending_acks: u64 = 0;
    let mut bands: u64 = 0;
    let mut bytes: u64 = 0;
    let fail = |e: String| {
        let mut g = error.lock().unwrap();
        if g.is_none() {
            *g = Some(e);
        }
    };
    loop {
        match rx.recv_timeout(Duration::from_millis(5)) {
            Ok(band) => match proto::send_msg(&mut stream, &Msg::HaloPush(band)) {
                Ok(n) => {
                    bands += 1;
                    bytes += n as u64;
                    pending_acks += 1;
                }
                Err(e) => {
                    fail(format!("halo push failed: {e}"));
                    return (bands, bytes);
                }
            },
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        if !drain_acks(&mut stream, &mut pending_acks, &fail) {
            return (bands, bytes);
        }
    }
    // queue closed: ack barrier so "done" means "delivered"
    let deadline = Instant::now() + ack_deadline;
    while pending_acks > 0 {
        if Instant::now() > deadline {
            fail(format!("timed out waiting for {pending_acks} halo ack(s)"));
            break;
        }
        if !drain_acks(&mut stream, &mut pending_acks, &fail) {
            break;
        }
    }
    (bands, bytes)
}

/// Drain whatever acks are buffered on the link; false on a link error.
fn drain_acks(
    stream: &mut TcpStream,
    pending: &mut u64,
    fail: &impl Fn(String),
) -> bool {
    loop {
        match proto::recv_msg(stream, Duration::from_secs(10)) {
            Ok(MsgRecv::Msg(Msg::HaloAck { .. }, _)) => *pending = pending.saturating_sub(1),
            Ok(MsgRecv::Idle) => return true,
            Ok(MsgRecv::Eof) => {
                fail("peer closed the link".to_string());
                return false;
            }
            Ok(MsgRecv::Msg(other, _)) => {
                fail(format!("protocol violation on peer link: unexpected {other:?}"));
                return false;
            }
            Err(e) => {
                fail(format!("peer link read failed: {e}"));
                return false;
            }
        }
    }
}

/// Rows `[a, b)` of `tile` as a standalone sub-grid.
fn sub_rows(tile: &DenseGrid, a: usize, b: usize, rest: usize) -> DenseGrid {
    let mut shape = tile.shape.clone();
    shape[0] = b - a;
    DenseGrid { shape, data: tile.data[a * rest..b * rest].to_vec() }
}

/// Copy `count` rows from `src` (starting `src_row`) into `dst`
/// (starting `dst_row`).
fn copy_rows(
    dst: &mut DenseGrid,
    dst_row: usize,
    src: &DenseGrid,
    src_row: usize,
    count: usize,
    rest: usize,
) {
    dst.data[dst_row * rest..(dst_row + count) * rest]
        .copy_from_slice(&src.data[src_row * rest..(src_row + count) * rest]);
}

/// The same per-tile evolution the mediated path runs node-side:
/// degenerate tiles are identity, everything else goes through the
/// sharded evolver (bitwise independent of the shard count).
fn evolve_local(
    evolver: &ShardedEvolver,
    req: &PlanRequest,
    shards: usize,
    grid: &DenseGrid,
    chunk: usize,
) -> anyhow::Result<DenseGrid> {
    let r = req.plan.spec.order;
    if grid.shape.iter().any(|&n| n <= 2 * r) {
        return Ok(grid.clone());
    }
    let (out, _, _) =
        evolver.evolve_fused(req.plan.spec, grid, chunk, shards, req.plan.method, chunk.max(1))?;
    Ok(out)
}

/// Extract round-`round` outgoing bands from every local tile and route
/// them: straight into local staging for co-located neighbours, onto the
/// peer links otherwise. Returns the number of remote pushes enqueued.
#[allow(clippy::too_many_arguments)]
fn push_bands(
    part: &Partition,
    req: &PlanRequest,
    mine: &[(usize, DenseGrid)],
    links: &HashMap<usize, PeerLink>,
    staging: &BandStaging,
    rest: usize,
    round: u64,
    stats: &mut PlanStats,
) {
    let plan = &req.plan;
    let mut route = |dest: usize, side: BandSide, data: Vec<f64>| {
        let owner = plan.owners[dest];
        if owner == plan.self_node {
            staging.deposit(round, dest as u64, side, data, 0);
        } else {
            stats.bands_sent += 1;
            if let Some(link) = links.get(&owner) {
                link.push(HaloBand {
                    epoch: plan.epoch,
                    round,
                    shard: dest as u64,
                    side,
                    data,
                });
            }
        }
    };
    for (s, tile) in mine {
        if let Some(band) = halo::outgoing_band_to_lower(part, *s) {
            route(*s - 1, BandSide::FromUpper, halo::extract_band(tile, band, rest));
        }
        if let Some(band) = halo::outgoing_band_to_upper(part, *s) {
            route(*s + 1, BandSide::FromLower, halo::extract_band(tile, band, rest));
        }
    }
}

/// Run every fused round of one exchange plan on this node. Returns the
/// evolved tiles (same shards and shapes as assigned) plus the node's
/// exchange accounting. `fail_after_rounds` is the node's fault
/// injection: at that round index the node sets `stop` and errors out,
/// simulating a node killed mid-exchange (the caller closes the
/// connection without replying).
pub fn run_plan(
    evolver: &ShardedEvolver,
    local_shards: usize,
    req: &PlanRequest,
    staging: &Arc<BandStaging>,
    stop: &AtomicBool,
    fail_after_rounds: Option<usize>,
) -> anyhow::Result<(Vec<(u64, DenseGrid)>, PlanStats)> {
    let plan = &req.plan;
    let part = &plan.part;
    let rest = part.row_elems();
    let n_shards = part.len();
    let order = plan.spec.order;
    anyhow::ensure!(plan.steps >= 1 && plan.fuse >= 1, "plan with no steps");
    anyhow::ensure!(
        part.halo == order * plan.fuse,
        "plan halo {} does not match order {} × fuse {}",
        part.halo,
        order,
        plan.fuse
    );
    let mut mine: Vec<(usize, DenseGrid)> = Vec::with_capacity(req.tiles.len());
    for (shard, tile) in &req.tiles {
        let s = *shard as usize;
        anyhow::ensure!(s < n_shards, "assigned shard {s} out of range for {n_shards} slab(s)");
        anyhow::ensure!(
            tile.shape == part.tile_shape(s),
            "assigned tile {s} shape {:?} does not match partition {:?}",
            tile.shape,
            part.tile_shape(s)
        );
        mine.push((s, tile.clone()));
    }
    let band_timeout = Duration::from_millis(plan.band_timeout_ms.max(1));

    // one link per distinct remote neighbour-owning node
    let mut links: HashMap<usize, PeerLink> = HashMap::new();
    for (s, _) in &mine {
        for nb in [s.checked_sub(1), Some(s + 1)].into_iter().flatten() {
            if nb >= n_shards {
                continue;
            }
            let owner = plan.owners[nb];
            if owner != plan.self_node && !links.contains_key(&owner) {
                links.insert(owner, PeerLink::connect(&plan.peers[owner], band_timeout)?);
            }
        }
    }

    let total_rounds = plan.steps.div_ceil(plan.fuse);
    let mut stats = PlanStats::default();
    let mut remaining = plan.steps;
    let mut sends_done = Instant::now();
    for round in 0..total_rounds {
        anyhow::ensure!(!stop.load(Ordering::SeqCst), "node stopping mid-plan");
        if let Some(limit) = fail_after_rounds {
            if round >= limit {
                stop.store(true, Ordering::SeqCst);
                anyhow::bail!("fault injection: node killed before round {round}");
            }
        }
        for link in links.values() {
            if let Some(e) = link.error() {
                anyhow::bail!("peer link failed: {e}");
            }
        }
        let chunk = plan.fuse.min(remaining);
        let h = order * chunk;

        if round == 0 {
            // fresh ghosts straight from extraction: plain full-tile
            // evolve, exactly one mediated round
            let t0 = Instant::now();
            for (_, tile) in mine.iter_mut() {
                *tile = evolve_local(evolver, req, local_shards, tile, chunk)?;
            }
            stats.compute_seconds += t0.elapsed().as_secs_f64();
        } else {
            // interior first, while round-(k-1) bands are in flight
            let interior_start = Instant::now();
            let mut interiors: Vec<Option<(DenseGrid, usize, usize)>> = Vec::new();
            for (s, cur) in mine.iter() {
                let slab = part.slabs[*s];
                let rows = slab.rows();
                let degenerate = cur.shape.iter().any(|&n| n <= 2 * order);
                let split = !degenerate && rows >= 2 * h;
                if !split {
                    interiors.push(None);
                    continue;
                }
                let sub = sub_rows(cur, slab.ghost_lo, slab.ghost_lo + rows, rest);
                let evolved = evolve_local(evolver, req, local_shards, &sub, chunk)?;
                // valid sub-local rows: depth-h cones must avoid a cut
                // edge; a coincident global edge is not a cut
                let lo_v = if slab.ghost_lo > 0 { h } else { 0 };
                let hi_v = if slab.ghost_hi > 0 { rows - h } else { rows };
                interiors.push(Some((evolved, lo_v, hi_v)));
            }
            let interior_end = Instant::now();
            stats.compute_seconds += (interior_end - interior_start).as_secs_f64();

            // wait for the bands, refresh ghosts, finish the boundaries
            let _g = span("cluster.peer_exchange", "cluster");
            let mut last_arrival: Option<Instant> = None;
            let mut wait_s = 0.0;
            let mut visible_s = 0.0;
            for (i, (s, cur)) in mine.iter_mut().enumerate() {
                let deadline = Instant::now() + band_timeout;
                let t0 = Instant::now();
                for (side, band) in [
                    (BandSide::FromLower, halo::incoming_band_from_lower(part, *s)),
                    (BandSide::FromUpper, halo::incoming_band_from_upper(part, *s)),
                ] {
                    let Some(band) = band else { continue };
                    let w0 = Instant::now();
                    let (data, arrived, wire) =
                        staging.take((round - 1) as u64, *s as u64, side, deadline)?;
                    wait_s += w0.elapsed().as_secs_f64();
                    anyhow::ensure!(
                        data.len() == band.count * rest,
                        "halo band for shard {s} has {} value(s), expected {}",
                        data.len(),
                        band.count * rest
                    );
                    stats.band_bytes_recv += wire;
                    if wire > 0 {
                        last_arrival =
                            Some(last_arrival.map_or(arrived, |a: Instant| a.max(arrived)));
                    }
                    halo::apply_band(cur, band, rest, &data);
                }
                visible_s += t0.elapsed().as_secs_f64();

                // boundary regions from fresh ghosts + pre-round rows
                let slab = part.slabs[*s];
                let rows = slab.rows();
                let c0 = Instant::now();
                match interiors[i].take() {
                    Some((evolved, lo_v, hi_v)) => {
                        let mut next = cur.clone();
                        if hi_v > lo_v {
                            copy_rows(
                                &mut next,
                                slab.ghost_lo + lo_v,
                                &evolved,
                                lo_v,
                                hi_v - lo_v,
                                rest,
                            );
                        }
                        if slab.ghost_lo > 0 {
                            let sub = sub_rows(cur, 0, slab.ghost_lo + 2 * h, rest);
                            let ev = evolve_local(evolver, req, local_shards, &sub, chunk)?;
                            copy_rows(&mut next, slab.ghost_lo, &ev, slab.ghost_lo, h, rest);
                        }
                        if slab.ghost_hi > 0 {
                            let base = slab.ghost_lo + rows - 2 * h;
                            let sub = sub_rows(cur, base, slab.tile_rows(), rest);
                            let ev = evolve_local(evolver, req, local_shards, &sub, chunk)?;
                            copy_rows(&mut next, slab.ghost_lo + rows - h, &ev, h, h, rest);
                        }
                        *cur = next;
                    }
                    // too short to split: ghosts are fresh now, evolve
                    // the whole tile (no overlap for this shard)
                    None => *cur = evolve_local(evolver, req, local_shards, cur, chunk)?,
                }
                stats.compute_seconds += c0.elapsed().as_secs_f64();
            }
            // hidden = band flight time not spent blocked; visible =
            // extraction + waits + application
            let flight = last_arrival
                .map(|a| a.saturating_duration_since(sends_done).as_secs_f64())
                .unwrap_or(0.0);
            stats.exchange_hidden_seconds += (flight - wait_s).max(0.0);
            stats.exchange_visible_seconds += visible_s;
        }

        remaining -= chunk;
        stats.rounds += 1;
        if remaining > 0 && n_shards > 1 {
            let t0 = Instant::now();
            push_bands(part, req, &mine, &links, staging, rest, round as u64, &mut stats);
            sends_done = Instant::now();
            stats.exchange_visible_seconds += (sends_done - t0).as_secs_f64();
        }
    }

    // ack barrier: every pushed band must be delivered before we report
    // done (a lost peer surfaces here even if our own waits all passed)
    for (_, link) in links.drain() {
        let (_, bytes) = link.finish()?;
        stats.band_bytes_sent += bytes;
    }
    Ok((mine.into_iter().map(|(s, t)| (s as u64, t)).collect(), stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staging_take_blocks_until_deposit_and_times_out() {
        let guard = register(42);
        let staging = Arc::clone(guard.staging());
        // timeout path
        let err = staging
            .take(0, 0, BandSide::FromLower, Instant::now() + Duration::from_millis(20))
            .unwrap_err()
            .to_string();
        assert!(err.contains("timed out waiting for halo band"), "{err}");
        // deposit from another thread unblocks a waiter
        let s2 = Arc::clone(&staging);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            s2.deposit(3, 1, BandSide::FromUpper, vec![1.0, 2.0], 64);
        });
        let (data, _, wire) = staging
            .take(3, 1, BandSide::FromUpper, Instant::now() + Duration::from_secs(5))
            .unwrap();
        t.join().unwrap();
        assert_eq!(data, vec![1.0, 2.0]);
        assert_eq!(wire, 64);
    }

    #[test]
    fn deposit_routes_by_epoch_and_unknown_epochs_are_dropped() {
        let band = |epoch| HaloBand {
            epoch,
            round: 0,
            shard: 0,
            side: BandSide::FromLower,
            data: vec![5.0],
        };
        let guard = register(7);
        assert!(deposit(band(7), 32));
        assert!(!deposit(band(8), 32), "unknown epoch must be dropped");
        let (data, _, _) = guard
            .staging()
            .take(0, 0, BandSide::FromLower, Instant::now() + Duration::from_secs(1))
            .unwrap();
        assert_eq!(data, vec![5.0]);
        drop(guard);
        assert!(!deposit(band(7), 32), "deregistered epoch must be dropped");
    }

    #[test]
    fn sub_rows_and_copy_rows_are_exact() {
        let g = DenseGrid::verification_input(&[6, 4], 1);
        let sub = sub_rows(&g, 2, 5, 4);
        assert_eq!(sub.shape, vec![3, 4]);
        assert_eq!(sub.data, g.data[8..20]);
        let mut dst = DenseGrid::zeros(&[6, 4]);
        copy_rows(&mut dst, 1, &sub, 0, 3, 4);
        assert_eq!(dst.data[4..16], g.data[8..20]);
        assert!(dst.data[..4].iter().all(|&v| v == 0.0));
    }
}
