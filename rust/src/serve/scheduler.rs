//! The shard scheduler: compiled per-tile kernels with an LRU plan cache,
//! and the step loop that drives compute + halo-exchange batches over the
//! worker pool.
//!
//! A *plan* is the per-(spec, tile-shape, method) precomputation a shard
//! kernel needs — for the native kernel, the stencil's non-zero taps
//! lowered to linear-offset/weight pairs against the tile's strides.
//! Plans are immutable and shared across threads (`Arc`), and cached in
//! an LRU keyed by `(spec, shape, method)` so a server handling a mixed
//! request stream compiles each shape once.
//!
//! Both kernels reproduce [`crate::stencil::reference::apply`] **bitwise**:
//! the native kernel iterates taps in the same dense-offset order with the
//! same accumulation order, so sharded multi-threaded evolution is
//! indistinguishable from the single-shard scalar oracle.

use super::halo;
use super::partition::Partition;
use super::pool::{Job, WorkerPool};
use crate::stencil::{reference, CoeffTensor, DenseGrid, StencilSpec};
use std::collections::HashMap;
use std::fmt;
use std::str::FromStr;
use std::sync::{Arc, Mutex};

/// Which kernel a plan compiles to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelMethod {
    /// Call the scalar reference oracle directly (specification kernel).
    Oracle,
    /// Precomputed linear-offset taps (same FP order, no index math).
    Taps,
}

impl fmt::Display for KernelMethod {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KernelMethod::Oracle => write!(f, "oracle"),
            KernelMethod::Taps => write!(f, "taps"),
        }
    }
}

impl FromStr for KernelMethod {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<KernelMethod> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "oracle" => KernelMethod::Oracle,
            "taps" | "native" => KernelMethod::Taps,
            other => anyhow::bail!("unknown kernel '{other}' (oracle|taps)"),
        })
    }
}

/// Cache key: everything a compiled plan depends on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// The stencil.
    pub spec: StencilSpec,
    /// Tile storage shape the plan is compiled for.
    pub shape: Vec<usize>,
    /// Kernel flavour.
    pub method: KernelMethod,
}

/// A compiled shard kernel for one (spec, tile shape, method).
#[derive(Debug)]
pub struct CompiledPlan {
    /// The key this plan was compiled for.
    pub key: PlanKey,
    coeffs: CoeffTensor,
    /// (linear offset, weight) per non-zero tap, dense-offset order.
    taps: Vec<(isize, f64)>,
}

impl CompiledPlan {
    /// Compile a plan (uses the repo-wide `paper_default` weights).
    pub fn compile(key: PlanKey) -> CompiledPlan {
        let coeffs = CoeffTensor::paper_default(key.spec);
        let dims = key.shape.len();
        let mut strides = vec![1isize; dims];
        for d in (0..dims.saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * key.shape[d + 1] as isize;
        }
        let taps = key
            .spec
            .dense_offsets()
            .iter()
            .enumerate()
            .filter(|(oi, _)| coeffs.data[*oi] != 0.0)
            .map(|(oi, off)| {
                let lin: isize = off.iter().zip(&strides).map(|(&o, &s)| o * s).sum();
                (lin, coeffs.data[oi])
            })
            .collect();
        CompiledPlan { key, coeffs, taps }
    }

    /// Apply one time step to a tile. Tiles too small to contain any
    /// interior point (edge shards wholly inside the global frozen band)
    /// are returned unchanged — their every point is boundary.
    pub fn apply(&self, a: &DenseGrid) -> DenseGrid {
        debug_assert_eq!(a.shape, self.key.shape, "tile does not match plan");
        let r = self.key.spec.order;
        if a.shape.iter().any(|&n| n <= 2 * r) {
            return a.clone();
        }
        match self.key.method {
            KernelMethod::Oracle => reference::apply(&self.coeffs, a),
            KernelMethod::Taps => self.apply_taps(a),
        }
    }

    /// Native kernel: same loop structure and accumulation order as the
    /// oracle (dense-offset order, zeros skipped), so the result is
    /// bitwise identical; only the per-point index arithmetic is hoisted.
    fn apply_taps(&self, a: &DenseGrid) -> DenseGrid {
        let r = self.key.spec.order;
        let mut b = a.clone();
        match *a.shape.as_slice() {
            [n0, n1] => {
                for i in r..n0 - r {
                    let row = i * n1;
                    for j in r..n1 - r {
                        let lin = row + j;
                        let mut acc = 0.0f64;
                        for &(off, w) in &self.taps {
                            acc += w * a.data[(lin as isize + off) as usize];
                        }
                        b.data[lin] = acc;
                    }
                }
            }
            [n0, n1, n2] => {
                for i in r..n0 - r {
                    for j in r..n1 - r {
                        let row = (i * n1 + j) * n2;
                        for k in r..n2 - r {
                            let lin = row + k;
                            let mut acc = 0.0f64;
                            for &(off, w) in &self.taps {
                                acc += w * a.data[(lin as isize + off) as usize];
                            }
                            b.data[lin] = acc;
                        }
                    }
                }
            }
            _ => unreachable!("grids are 2D or 3D"),
        }
        b
    }
}

/// Cache counters, readable while serving.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from cache.
    pub hits: u64,
    /// Lookups that compiled a new plan.
    pub misses: u64,
    /// Plans evicted to stay within capacity.
    pub evictions: u64,
    /// Plans currently resident.
    pub len: usize,
}

struct CacheEntry {
    plan: Arc<CompiledPlan>,
    last_used: u64,
}

struct CacheInner {
    map: HashMap<PlanKey, CacheEntry>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Thread-safe LRU cache of compiled plans keyed by (spec, shape, method).
pub struct PlanCache {
    capacity: usize,
    inner: Mutex<CacheInner>,
}

impl PlanCache {
    /// New cache holding at most `capacity.max(1)` plans.
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            capacity: capacity.max(1),
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
        }
    }

    /// Fetch (or compile and insert) the plan for a key.
    pub fn get(&self, key: PlanKey) -> Arc<CompiledPlan> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(entry) = inner.map.get_mut(&key) {
            entry.last_used = tick;
            inner.hits += 1;
            return Arc::clone(&entry.plan);
        }
        inner.misses += 1;
        let plan = Arc::new(CompiledPlan::compile(key.clone()));
        inner.map.insert(key, CacheEntry { plan: Arc::clone(&plan), last_used: tick });
        if inner.map.len() > self.capacity {
            if let Some(oldest) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                inner.map.remove(&oldest);
                inner.evictions += 1;
            }
        }
        plan
    }

    /// Current counters.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            len: inner.map.len(),
        }
    }
}

/// Multi-threaded sharded evolution: partition → per-step compute batches
/// with a barrier → halo exchange → assemble.
pub struct ShardedEvolver {
    pool: Arc<WorkerPool>,
    cache: Arc<PlanCache>,
}

impl ShardedEvolver {
    /// Evolver with its own pool of `workers` threads and a default-sized
    /// plan cache.
    pub fn new(workers: usize) -> ShardedEvolver {
        ShardedEvolver::with_parts(Arc::new(WorkerPool::new(workers)), Arc::new(PlanCache::new(32)))
    }

    /// Evolver over an existing pool and cache (shared with a server).
    pub fn with_parts(pool: Arc<WorkerPool>, cache: Arc<PlanCache>) -> ShardedEvolver {
        ShardedEvolver { pool, cache }
    }

    /// The worker pool.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// The plan cache.
    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    /// Evolve `grid` by `steps` time steps of `spec`, decomposed into (up
    /// to) `shards` slabs executed on the pool. Bitwise equal to
    /// [`reference::evolve`] with `paper_default` weights.
    pub fn evolve(
        &self,
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        shards: usize,
        method: KernelMethod,
    ) -> anyhow::Result<DenseGrid> {
        self.evolve_sharded(spec, grid, steps, shards, method)
            .map(|(grid, _)| grid)
    }

    /// [`ShardedEvolver::evolve`], additionally returning the shard count
    /// actually used (after clamping) — the number the report should
    /// carry, rather than re-deriving the partition at the call site.
    pub fn evolve_sharded(
        &self,
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        shards: usize,
        method: KernelMethod,
    ) -> anyhow::Result<(DenseGrid, usize)> {
        anyhow::ensure!(
            grid.shape.len() == spec.dims,
            "grid shape {:?} does not match {spec}",
            grid.shape
        );
        anyhow::ensure!(
            grid.shape.iter().all(|&n| n > 2 * spec.order),
            "grid {:?} too small for order-{} stencil",
            grid.shape,
            spec.order
        );
        let part = Arc::new(Partition::new(&grid.shape, shards, spec.order)?);
        let n_shards = part.len();
        if steps == 0 {
            return Ok((grid.clone(), n_shards));
        }
        let plans: Vec<Arc<CompiledPlan>> = (0..n_shards)
            .map(|s| {
                self.cache
                    .get(PlanKey { spec, shape: part.tile_shape(s), method })
            })
            .collect();
        let tiles: Arc<Vec<Mutex<DenseGrid>>> =
            Arc::new(part.extract(grid).into_iter().map(Mutex::new).collect());

        for step in 0..steps {
            let compute: Vec<Job> = (0..n_shards)
                .map(|s| {
                    let tiles = Arc::clone(&tiles);
                    let plan = Arc::clone(&plans[s]);
                    let job: Job = Box::new(move || {
                        let mut tile = tiles[s].lock().unwrap();
                        *tile = plan.apply(&tile);
                    });
                    job
                })
                .collect();
            self.pool.run_batch(compute)?;

            if step + 1 < steps && n_shards > 1 {
                let exchange: Vec<Job> = (0..n_shards)
                    .map(|s| {
                        let tiles = Arc::clone(&tiles);
                        let part = Arc::clone(&part);
                        let job: Job = Box::new(move || {
                            halo::refresh_ghosts(&part, &tiles, s);
                        });
                        job
                    })
                    .collect();
                self.pool.run_batch(exchange)?;
            }
        }

        let guards: Vec<std::sync::MutexGuard<'_, DenseGrid>> =
            tiles.iter().map(|m| m.lock().unwrap()).collect();
        let refs: Vec<&DenseGrid> = guards.iter().map(|g| &**g).collect();
        Ok((part.assemble(&refs)?, n_shards))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taps_kernel_matches_oracle_bitwise() {
        for spec in [
            StencilSpec::box2d(1),
            StencilSpec::star2d(2),
            StencilSpec::diag2d(1),
            StencilSpec::box3d(1),
            StencilSpec::star3d(2),
        ] {
            let shape: Vec<usize> = vec![4 * spec.order + 3; spec.dims];
            let a = DenseGrid::verification_input(&shape, 13);
            let key = PlanKey { spec, shape: shape.clone(), method: KernelMethod::Taps };
            let plan = CompiledPlan::compile(key);
            let want = reference::apply(&CoeffTensor::paper_default(spec), &a);
            assert_eq!(plan.apply(&a), want, "{spec}");
        }
    }

    #[test]
    fn degenerate_tile_is_identity() {
        let spec = StencilSpec::box2d(2);
        // 4 rows = 2r: no interior row, must be a pure copy
        let a = DenseGrid::verification_input(&[4, 9], 1);
        for method in [KernelMethod::Oracle, KernelMethod::Taps] {
            let plan =
                CompiledPlan::compile(PlanKey { spec, shape: vec![4, 9], method });
            assert_eq!(plan.apply(&a), a, "{method}");
        }
    }

    #[test]
    fn lru_cache_hits_and_evicts() {
        let cache = PlanCache::new(2);
        let key = |n: usize| PlanKey {
            spec: StencilSpec::box2d(1),
            shape: vec![n, n],
            method: KernelMethod::Taps,
        };
        let a = cache.get(key(8));
        let _b = cache.get(key(9));
        assert_eq!(cache.stats().misses, 2);
        // hit keeps 8 recent
        let a2 = cache.get(key(8));
        assert!(Arc::ptr_eq(&a, &a2));
        assert_eq!(cache.stats().hits, 1);
        // third key evicts the LRU entry (9)
        let _c = cache.get(key(10));
        let st = cache.stats();
        assert_eq!((st.evictions, st.len), (1, 2));
        // 9 was evicted → miss again (which in turn evicts 8, now LRU)
        cache.get(key(9));
        let st = cache.stats();
        assert_eq!(st.misses, 4);
        assert_eq!(st.evictions, 2);
        // 10 is still resident → hit
        cache.get(key(10));
        assert_eq!(cache.stats().hits, 2);
    }

    #[test]
    fn sharded_evolve_matches_reference_bitwise() {
        let spec = StencilSpec::box2d(1);
        let grid = DenseGrid::verification_input(&[24, 18], 0xC0FFEE);
        let coeffs = CoeffTensor::paper_default(spec);
        let want = reference::evolve(&coeffs, &grid, 3);
        for workers in [1usize, 4] {
            let ev = ShardedEvolver::new(workers);
            for shards in [1usize, 2, 5] {
                for method in [KernelMethod::Oracle, KernelMethod::Taps] {
                    let got = ev.evolve(spec, &grid, 3, shards, method).unwrap();
                    assert_eq!(got, want, "workers={workers} shards={shards} {method}");
                }
            }
        }
    }

    #[test]
    fn evolve_rejects_mismatched_grid() {
        let ev = ShardedEvolver::new(1);
        let g2 = DenseGrid::verification_input(&[8, 8], 0);
        assert!(ev
            .evolve(StencilSpec::box3d(1), &g2, 1, 2, KernelMethod::Taps)
            .is_err());
        let tiny = DenseGrid::verification_input(&[4, 4], 0);
        assert!(ev
            .evolve(StencilSpec::box2d(2), &tiny, 1, 1, KernelMethod::Taps)
            .is_err());
    }

    #[test]
    fn zero_steps_is_identity() {
        let ev = ShardedEvolver::new(2);
        let g = DenseGrid::verification_input(&[9, 9], 4);
        let out = ev
            .evolve(StencilSpec::box2d(1), &g, 0, 3, KernelMethod::Taps)
            .unwrap();
        assert_eq!(out, g);
    }
}
