//! The shard scheduler: compiled per-tile kernels with an LRU plan cache,
//! and the step loop that drives compute + halo-exchange batches over the
//! worker pool.
//!
//! A *plan* is the per-(spec, tile-shape, method, time-tile depth)
//! precomputation a shard kernel needs — for the native kernel, the
//! stencil's non-zero taps lowered to linear-offset/weight pairs against
//! the tile's strides. Plans are immutable and shared across threads
//! (`Arc`), and cached in an LRU keyed by `(spec, shape, method, steps)`
//! so a server handling a mixed request stream compiles each shape once.
//! A plan with `steps = T > 1` advances `T` fused time steps per
//! application (temporal blocking behind `order × T`-deep ghosts);
//! [`ShardedEvolver::evolve_fused`] exchanges halos only between fused
//! applications, bitwise identically to the unfused step loop.
//!
//! The oracle/taps kernels reproduce [`crate::stencil::reference::apply`]
//! **bitwise**: the native kernel iterates taps in the same dense-offset
//! order with the same accumulation order, so sharded multi-threaded
//! evolution is indistinguishable from the single-shard scalar oracle.
//! The `outer` kernel (and tuned plans compiled to host kernels) runs the
//! paper's algorithm through the kernel IR instead: it matches the oracle
//! within 1e-9, and its per-output accumulation order is position-
//! independent, so sharded execution stays bitwise equal to single-shard
//! execution of the same kernel.

use super::halo;
use super::partition::Partition;
use super::pool::{Job, WorkerPool};
use crate::codegen::{Method, OuterParams};
use crate::kir::{Engine, HostKernel};
use crate::obs::span::span_arg;
use crate::obs::{audit, registry};
use crate::stencil::{reference, CoeffTensor, DenseGrid, StencilSpec};
use crate::sim::SimConfig;
use crate::tune::{cost, TuneDb, TunePlan};
use std::collections::HashMap;
use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Which kernel a plan compiles to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelMethod {
    /// Call the scalar reference oracle directly (specification kernel).
    Oracle,
    /// Precomputed linear-offset taps (same FP order, no index math).
    Taps,
    /// The paper's outer-product scatter algorithm, compiled through the
    /// kernel IR ([`crate::kir::HostKernel`]) and executed natively on
    /// the host. Matches the oracle within 1e-9 (not bitwise: the
    /// outer-product accumulation order differs from the gather sweep's),
    /// and sharded execution is bitwise identical to single-shard
    /// execution of the same kernel.
    Outer,
    /// Like [`KernelMethod::Taps`], but plan compilation consults the
    /// tuning database (when the cache has one): a matched tuned plan is
    /// compiled to a **real host kernel** through the kernel IR (outer /
    /// autovec / scalar plans; grid-restructuring plans such as DLT/TV
    /// fall back to the bitwise taps kernel, as does every request when
    /// the database has no entry). The match is surfaced through
    /// [`TunedInfo`] and the serve metrics.
    Tuned,
}

impl fmt::Display for KernelMethod {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KernelMethod::Oracle => write!(f, "oracle"),
            KernelMethod::Taps => write!(f, "taps"),
            KernelMethod::Outer => write!(f, "outer"),
            KernelMethod::Tuned => write!(f, "tuned"),
        }
    }
}

impl FromStr for KernelMethod {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<KernelMethod> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "oracle" => KernelMethod::Oracle,
            "taps" | "native" => KernelMethod::Taps,
            "outer" | "kir" => KernelMethod::Outer,
            "tuned" => KernelMethod::Tuned,
            other => anyhow::bail!("unknown kernel '{other}' (oracle|taps|outer|tuned)"),
        })
    }
}

/// The tuning-database record a compiled shard plan was matched with.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedInfo {
    /// Table-3-style label of the tuned plan (e.g. `p-j8`, `o-i4`).
    pub label: String,
    /// The tuned plan itself (compiled to a host kernel when supported).
    pub plan: TunePlan,
    /// The tuned plan's simulated cycles per point per step.
    pub sim_cycles_per_point: f64,
    /// Domain extent the plan was tuned at.
    pub tuned_n: usize,
}

/// Cache key: everything a compiled plan depends on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// The stencil.
    pub spec: StencilSpec,
    /// Tile storage shape the plan is compiled for.
    pub shape: Vec<usize>,
    /// Kernel flavour.
    pub method: KernelMethod,
    /// Fused time steps one `apply` advances (temporal blocking; 1 =
    /// classic single sweep). Tiles must carry ghosts of depth
    /// `order * steps` for a fused application to be exact.
    pub steps: usize,
}

impl PlanKey {
    /// Single-step key (the classic pre-temporal-blocking plan).
    pub fn single(spec: StencilSpec, shape: Vec<usize>, method: KernelMethod) -> PlanKey {
        PlanKey { spec, shape, method, steps: 1 }
    }
}

/// A compiled shard kernel for one (spec, tile shape, method).
#[derive(Debug)]
pub struct CompiledPlan {
    /// The key this plan was compiled for.
    pub key: PlanKey,
    /// Tuning-database match, when the plan was compiled through a cache
    /// holding a [`TuneDb`] and the database had an entry for this
    /// stencil on the tuned machine.
    pub tuned: Option<TunedInfo>,
    coeffs: CoeffTensor,
    /// (linear offset, weight) per non-zero tap, dense-offset order.
    taps: Vec<(isize, f64)>,
    /// KIR-compiled host kernel ([`KernelMethod::Outer`], and `Tuned`
    /// plans the host backend supports); `None` falls back to the
    /// bitwise taps kernel.
    host: Option<HostKernel>,
}

impl CompiledPlan {
    /// Compile a plan (uses the repo-wide `paper_default` weights) with
    /// the default compiled host engine.
    pub fn compile(key: PlanKey) -> CompiledPlan {
        CompiledPlan::compile_with_engine(key, Engine::default())
    }

    /// Compile a plan whose KIR host kernels (if any) execute on
    /// `engine`.
    pub fn compile_with_engine(key: PlanKey, engine: Engine) -> CompiledPlan {
        debug_assert!(key.steps >= 1, "a plan advances at least one step per apply");
        let host = match key.method {
            KernelMethod::Outer => {
                host_kernel(&key, Method::Outer(OuterParams::paper_best(key.spec)), engine)
            }
            _ => None,
        };
        let coeffs = CoeffTensor::paper_default(key.spec);
        let dims = key.shape.len();
        let mut strides = vec![1isize; dims];
        for d in (0..dims.saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * key.shape[d + 1] as isize;
        }
        let taps = key
            .spec
            .dense_offsets()
            .iter()
            .enumerate()
            .filter(|(oi, _)| coeffs.data[*oi] != 0.0)
            .map(|(oi, off)| {
                let lin: isize = off.iter().zip(&strides).map(|(&o, &s)| o * s).sum();
                (lin, coeffs.data[oi])
            })
            .collect();
        CompiledPlan { key, tuned: None, coeffs, taps, host }
    }

    /// Non-marker KIR operations of the compiled host kernel, when this
    /// plan has one.
    pub fn host_ops(&self) -> Option<usize> {
        self.host.as_ref().map(|k| k.op_count())
    }

    /// Label of the compiled host kernel's plan, when this plan has one.
    pub fn host_label(&self) -> Option<&str> {
        self.host.as_ref().map(|k| k.label())
    }

    /// Engine the compiled host kernel executes on, when this plan has
    /// one.
    pub fn host_engine(&self) -> Option<Engine> {
        self.host.as_ref().map(|k| k.engine())
    }

    /// Apply the plan's `key.steps` fused time steps to a tile on one
    /// thread (see [`CompiledPlan::apply_with`]). Tiles too small to
    /// contain any interior point (edge shards wholly inside the global
    /// frozen band) are returned unchanged — their every point is
    /// boundary.
    pub fn apply(&self, a: &DenseGrid) -> DenseGrid {
        self.apply_with(a, 1)
    }

    /// Apply the plan's `key.steps` fused time steps to a tile, allowing
    /// a KIR host kernel's compiled engine up to `threads` worker
    /// threads (0 = one per available core; the taps/oracle kernels and
    /// the interpret engine always run on the calling thread). Every
    /// step freezes the tile's `r`-deep boundary band, so a fused
    /// application is bitwise identical to `key.steps` single-step
    /// applications; the result is bitwise independent of `threads`.
    pub fn apply_with(&self, a: &DenseGrid, threads: usize) -> DenseGrid {
        debug_assert_eq!(a.shape, self.key.shape, "tile does not match plan");
        let r = self.key.spec.order;
        if a.shape.iter().any(|&n| n <= 2 * r) {
            return a.clone();
        }
        match self.key.method {
            KernelMethod::Oracle => self.repeat(a, |t| reference::apply(&self.coeffs, t)),
            KernelMethod::Taps => self.repeat(a, |t| self.apply_taps(t)),
            // the KIR host kernel when one compiled (already fused to
            // key.steps); the bitwise taps kernel otherwise (degenerate
            // tiles, unsupported tuned plans, or no tuning-database
            // match)
            KernelMethod::Outer | KernelMethod::Tuned => match &self.host {
                Some(k) => {
                    debug_assert_eq!(k.steps(), self.key.steps);
                    k.apply_with(a, k.engine(), threads)
                }
                None => self.repeat(a, |t| self.apply_taps(t)),
            },
        }
    }

    /// `key.steps` tile-local applications of a single-step kernel — the
    /// reference form of temporal fusion (no exchange, band frozen per
    /// step).
    fn repeat(&self, a: &DenseGrid, f: impl Fn(&DenseGrid) -> DenseGrid) -> DenseGrid {
        let mut cur = f(a);
        for _ in 1..self.key.steps.max(1) {
            cur = f(&cur);
        }
        cur
    }

    /// Native kernel: same loop structure and accumulation order as the
    /// oracle (dense-offset order, zeros skipped), so the result is
    /// bitwise identical; only the per-point index arithmetic is hoisted.
    fn apply_taps(&self, a: &DenseGrid) -> DenseGrid {
        let r = self.key.spec.order;
        let mut b = a.clone();
        match *a.shape.as_slice() {
            [n0, n1] => {
                for i in r..n0 - r {
                    let row = i * n1;
                    for j in r..n1 - r {
                        let lin = row + j;
                        let mut acc = 0.0f64;
                        for &(off, w) in &self.taps {
                            acc += w * a.data[(lin as isize + off) as usize];
                        }
                        b.data[lin] = acc;
                    }
                }
            }
            [n0, n1, n2] => {
                for i in r..n0 - r {
                    for j in r..n1 - r {
                        let row = (i * n1 + j) * n2;
                        for k in r..n2 - r {
                            let lin = row + k;
                            let mut acc = 0.0f64;
                            for &(off, w) in &self.taps {
                                acc += w * a.data[(lin as isize + off) as usize];
                            }
                            b.data[lin] = acc;
                        }
                    }
                }
            }
            _ => unreachable!("grids are 2D or 3D"),
        }
        b
    }
}

/// Compile the KIR host kernel for a plan key (fused to `key.steps`
/// time steps per application), if the tile shape and method admit one.
/// Degenerate tiles (no interior), grid-restructuring methods, and
/// methods the fuser rejects yield `None` — the caller falls back to
/// the bitwise taps kernel (repeated `key.steps` times). Host kernels
/// run on the default §5.1 machine shape (8-lane vectors, 8×8 tiles),
/// executed by `engine`.
fn host_kernel(key: &PlanKey, method: Method, engine: Engine) -> Option<HostKernel> {
    if key.shape.iter().any(|&s| s <= 2 * key.spec.order) {
        return None;
    }
    HostKernel::compile_fused(&SimConfig::default(), key.spec, &key.shape, method, key.steps)
        .ok()
        .map(|mut k| {
            k.set_engine(engine);
            k
        })
}

/// Cache counters, readable while serving.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from cache.
    pub hits: u64,
    /// Lookups that compiled a new plan.
    pub misses: u64,
    /// Plans evicted to stay within capacity.
    pub evictions: u64,
    /// Compiled plans that were matched with a tuning-database entry.
    pub tuned_hits: u64,
    /// Plans currently resident.
    pub len: usize,
}

struct CacheEntry {
    plan: Arc<CompiledPlan>,
    last_used: u64,
}

struct CacheInner {
    map: HashMap<PlanKey, CacheEntry>,
    /// Per-spec tuning-database resolution, memoized: the DB (immutable
    /// once handed to the cache) is scanned at most once per stencil.
    tuned_memo: HashMap<StencilSpec, Option<TunedInfo>>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    tuned_hits: u64,
}

/// Thread-safe LRU cache of compiled plans keyed by (spec, shape, method).
///
/// A cache built with [`PlanCache::with_tune_db`] consults the tuning
/// database **before** compiling a shard kernel: plans compiled for
/// [`KernelMethod::Tuned`] are matched (by stencil + machine fingerprint)
/// with the database's best entry and carry it as
/// [`CompiledPlan::tuned`].
pub struct PlanCache {
    capacity: usize,
    inner: Mutex<CacheInner>,
    tune: Option<(Arc<TuneDb>, String)>,
    /// Engine for KIR host kernels compiled by this cache.
    engine: Engine,
}

impl PlanCache {
    /// New cache holding at most `capacity.max(1)` plans (compiled host
    /// engine by default).
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache::build(capacity, None)
    }

    /// New cache that consults `db` (entries for machine `fingerprint`)
    /// when compiling [`KernelMethod::Tuned`] plans.
    pub fn with_tune_db(capacity: usize, db: Arc<TuneDb>, fingerprint: String) -> PlanCache {
        PlanCache::build(capacity, Some((db, fingerprint)))
    }

    fn build(capacity: usize, tune: Option<(Arc<TuneDb>, String)>) -> PlanCache {
        PlanCache {
            capacity: capacity.max(1),
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                tuned_memo: HashMap::new(),
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                tuned_hits: 0,
            }),
            tune,
            engine: Engine::default(),
        }
    }

    /// Select the engine for host kernels this cache compiles (set
    /// before sharing the cache; already-resident plans are unaffected).
    pub fn set_engine(&mut self, engine: Engine) {
        self.engine = engine;
    }

    /// Engine for host kernels this cache compiles.
    pub fn engine(&self) -> Engine {
        self.engine
    }

    /// The tuned-plan label this cache resolves for a stencil (the same
    /// lookup plan compilation performs), if its database has one.
    pub fn tuned_label(&self, spec: StencilSpec) -> Option<String> {
        self.tuned_info(spec).map(|i| i.label)
    }

    /// The full tuning-database match for a stencil (memoized, same
    /// lookup plan compilation performs), if the database has one — the
    /// cost-model auditor reads the matched plan from here without
    /// compiling anything.
    pub fn tuned_info(&self, spec: StencilSpec) -> Option<TunedInfo> {
        let mut inner = self.inner.lock().unwrap();
        Self::resolve_tuned(&self.tune, &mut inner.tuned_memo, spec)
    }

    /// The time-tile depth the tuning database's plan for this stencil
    /// won at (1 when there is no match or the plan is single-sweep).
    /// The serving layer adopts it for `tuned`-kernel requests so a
    /// fused tune winner actually runs fused — still capped per request
    /// by [`crate::serve::Partition::max_fuse`].
    pub fn tuned_fuse(&self, spec: StencilSpec) -> usize {
        let mut inner = self.inner.lock().unwrap();
        Self::resolve_tuned(&self.tune, &mut inner.tuned_memo, spec)
            .map(|i| i.plan.steps.max(1))
            .unwrap_or(1)
    }

    /// True when `tuned`-kernel requests for this stencil resolve to a
    /// plan the host backend can compile (a database match with an
    /// outer/autovec/scalar plan); false when they fall back to the
    /// bitwise taps kernel (no match, or a grid-restructuring DLT/TV
    /// plan). The serving layer keeps the *bitwise* verification bar in
    /// the false case; in the true case it verifies at 1e-9 — even for
    /// the rare per-tile taps/identity fallbacks (degenerate tiles),
    /// which are copies and cannot introduce error anyway.
    pub fn tuned_runs_host(&self, spec: StencilSpec) -> bool {
        let mut inner = self.inner.lock().unwrap();
        Self::resolve_tuned(&self.tune, &mut inner.tuned_memo, spec)
            .map(|i| !matches!(i.plan.to_method(), Method::Dlt | Method::Tv))
            .unwrap_or(false)
    }

    /// Memoized tuning-database resolution for a stencil.
    fn resolve_tuned(
        tune: &Option<(Arc<TuneDb>, String)>,
        memo: &mut HashMap<StencilSpec, Option<TunedInfo>>,
        spec: StencilSpec,
    ) -> Option<TunedInfo> {
        if let Some(cached) = memo.get(&spec) {
            return cached.clone();
        }
        let resolved = tune.as_ref().and_then(|(db, fp)| {
            db.best_for(spec, fp).map(|e| TunedInfo {
                label: e.plan.label(spec.dims),
                plan: e.plan,
                sim_cycles_per_point: e.cycles_per_point,
                tuned_n: e.n,
            })
        });
        memo.insert(spec, resolved.clone());
        resolved
    }

    /// Fetch (or compile and insert) the plan for a key.
    pub fn get(&self, key: PlanKey) -> Arc<CompiledPlan> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(entry) = inner.map.get_mut(&key) {
            entry.last_used = tick;
            inner.hits += 1;
            return Arc::clone(&entry.plan);
        }
        inner.misses += 1;
        let mut compiled = CompiledPlan::compile_with_engine(key.clone(), self.engine);
        // the tuning DB is consulted only on the compile path (and at
        // most once per stencil thanks to the memo), so the steady-state
        // hit path never pays the lookup
        if key.method == KernelMethod::Tuned {
            if let Some(info) = Self::resolve_tuned(&self.tune, &mut inner.tuned_memo, key.spec) {
                inner.tuned_hits += 1;
                // compile the tuned plan to a real host kernel when the
                // host backend supports it (outer/autovec/scalar)
                compiled.host = host_kernel(&key, info.plan.to_method(), self.engine);
                compiled.tuned = Some(info);
            }
        }
        let plan = Arc::new(compiled);
        inner.map.insert(key, CacheEntry { plan: Arc::clone(&plan), last_used: tick });
        if inner.map.len() > self.capacity {
            if let Some(oldest) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                inner.map.remove(&oldest);
                inner.evictions += 1;
            }
        }
        plan
    }

    /// Current counters.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            tuned_hits: inner.tuned_hits,
            len: inner.map.len(),
        }
    }
}

/// Multi-threaded sharded evolution: partition → per-step compute batches
/// with a barrier → halo exchange → assemble.
pub struct ShardedEvolver {
    pool: Arc<WorkerPool>,
    cache: Arc<PlanCache>,
}

impl ShardedEvolver {
    /// Evolver with its own pool of `workers` threads and a default-sized
    /// plan cache.
    pub fn new(workers: usize) -> ShardedEvolver {
        ShardedEvolver::with_parts(Arc::new(WorkerPool::new(workers)), Arc::new(PlanCache::new(32)))
    }

    /// Evolver over an existing pool and cache (shared with a server).
    pub fn with_parts(pool: Arc<WorkerPool>, cache: Arc<PlanCache>) -> ShardedEvolver {
        ShardedEvolver { pool, cache }
    }

    /// The worker pool.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// The plan cache.
    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    /// Evolve `grid` by `steps` time steps of `spec`, decomposed into (up
    /// to) `shards` slabs executed on the pool. Bitwise equal to
    /// [`reference::evolve`] with `paper_default` weights.
    pub fn evolve(
        &self,
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        shards: usize,
        method: KernelMethod,
    ) -> anyhow::Result<DenseGrid> {
        self.evolve_sharded(spec, grid, steps, shards, method)
            .map(|(grid, _)| grid)
    }

    /// [`ShardedEvolver::evolve`], additionally returning the shard count
    /// actually used (after clamping) — the number the report should
    /// carry, rather than re-deriving the partition at the call site.
    pub fn evolve_sharded(
        &self,
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        shards: usize,
        method: KernelMethod,
    ) -> anyhow::Result<(DenseGrid, usize)> {
        self.evolve_fused(spec, grid, steps, shards, method, 1)
            .map(|(grid, shards, _)| (grid, shards))
    }

    /// Temporally blocked sharded evolution: fuse up to `fuse` time
    /// steps per kernel application behind ghosts of depth
    /// `order * T`, exchanging halos only every `T` steps.
    ///
    /// The effective depth `T` is capped by [`Partition::max_fuse`] so a
    /// deep halo never starves the shard count, and by `steps`. Halo
    /// exchanges per request drop from `steps - 1` to
    /// `ceil(steps / T) - 1`, and so do the per-step embed/extract
    /// round-trips and pool barriers. Every kernel application freezes
    /// the tile's `r`-deep band per fused step, so the result is bitwise
    /// identical to the unfused (`fuse = 1`) evolution of the same
    /// kernel. Returns the evolved grid, the shard count used, and the
    /// fusion accounting.
    pub fn evolve_fused(
        &self,
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        shards: usize,
        method: KernelMethod,
        fuse: usize,
    ) -> anyhow::Result<(DenseGrid, usize, FuseReport)> {
        anyhow::ensure!(
            grid.shape.len() == spec.dims,
            "grid shape {:?} does not match {spec}",
            grid.shape
        );
        anyhow::ensure!(
            grid.shape.iter().all(|&n| n > 2 * spec.order),
            "grid {:?} too small for order-{} stencil",
            grid.shape,
            spec.order
        );
        let t = Partition::max_fuse(grid.shape[0], spec.order, shards, fuse)
            .min(steps.max(1));
        let part = Arc::new(Partition::new(&grid.shape, shards, spec.order * t)?);
        let n_shards = part.len();
        if steps == 0 {
            return Ok((grid.clone(), n_shards, FuseReport { fuse_steps: t, halo_exchanges: 0 }));
        }
        // plans per (shard, chunk depth): the remainder chunk (steps % T)
        // compiles its own shallower fused kernels
        let plans_for = |chunk: usize| -> Vec<Arc<CompiledPlan>> {
            (0..n_shards)
                .map(|s| {
                    self.cache.get(PlanKey {
                        spec,
                        shape: part.tile_shape(s),
                        method,
                        steps: chunk,
                    })
                })
                .collect()
        };
        let tiles: Arc<Vec<Mutex<DenseGrid>>> =
            Arc::new(part.extract(grid).into_iter().map(Mutex::new).collect());
        // per-shard kernel CPU nanoseconds, accumulated across chunks —
        // feeds the shard-imbalance gauge and the cost-model auditor
        let shard_nanos: Arc<Vec<AtomicU64>> =
            Arc::new((0..n_shards).map(|_| AtomicU64::new(0)).collect());
        // a single shard may drive every core through the compiled
        // engine's row-group threading; with multiple shards the pool's
        // shard-level parallelism owns the cores (results are bitwise
        // independent of this choice)
        let kernel_threads = if n_shards == 1 { 0 } else { 1 };

        let mut full_plans: Option<Vec<Arc<CompiledPlan>>> = None;
        let mut remaining = steps;
        let mut halo_exchanges = 0usize;
        while remaining > 0 {
            let chunk = t.min(remaining);
            let plans = if chunk == t {
                full_plans.get_or_insert_with(|| plans_for(t)).clone()
            } else {
                plans_for(chunk)
            };
            let compute: Vec<Job> = (0..n_shards)
                .map(|s| {
                    let tiles = Arc::clone(&tiles);
                    let plan = Arc::clone(&plans[s]);
                    let shard_nanos = Arc::clone(&shard_nanos);
                    let job: Job = Box::new(move || {
                        let _g = span_arg("serve.kernel", "serve", ("shard", s as f64));
                        let mut tile = tiles[s].lock().unwrap();
                        let t0 = Instant::now();
                        *tile = plan.apply_with(&tile, kernel_threads);
                        shard_nanos[s]
                            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    });
                    job
                })
                .collect();
            self.pool.run_batch(compute)?;
            remaining -= chunk;

            if remaining > 0 && n_shards > 1 {
                let exchange: Vec<Job> = (0..n_shards)
                    .map(|s| {
                        let tiles = Arc::clone(&tiles);
                        let part = Arc::clone(&part);
                        let job: Job = Box::new(move || {
                            halo::refresh_ghosts(&part, &tiles, s);
                        });
                        job
                    })
                    .collect();
                self.pool.run_batch(exchange)?;
                halo_exchanges += 1;
            }
        }

        let nanos: Vec<u64> = shard_nanos.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        record_shard_times(&nanos);
        self.audit_observe(spec, grid, steps, method, t, &nanos);

        let guards: Vec<std::sync::MutexGuard<'_, DenseGrid>> =
            tiles.iter().map(|m| m.lock().unwrap()).collect();
        let refs: Vec<&DenseGrid> = guards.iter().map(|g| &**g).collect();
        Ok((
            part.assemble(&refs)?,
            n_shards,
            FuseReport { fuse_steps: t, halo_exchanges },
        ))
    }

    /// Feed one evolution into the cost-model auditor: measured per-shard
    /// kernel CPU seconds against the analytic model's prediction for the
    /// plan this request ran (`outer`, or a tuning-database match). The
    /// oracle/taps kernels have no cost model — the auditor skips them.
    fn audit_observe(
        &self,
        spec: StencilSpec,
        grid: &DenseGrid,
        steps: usize,
        method: KernelMethod,
        t: usize,
        nanos: &[u64],
    ) {
        let measured_seconds = nanos.iter().sum::<u64>() as f64 / 1e9;
        let interior: usize = grid.shape.iter().map(|&d| d - 2 * spec.order).product();
        let point_steps = (interior * steps) as f64;
        let n = grid.shape[0] - 2 * spec.order;
        let tune_plan = match method {
            KernelMethod::Outer => Some(TunePlan::paper_default(spec).fused(t)),
            KernelMethod::Tuned => {
                // predictions only make sense for DB matches the host
                // backend actually ran; taps fallbacks are unmodelled
                if self.cache.tuned_runs_host(spec) {
                    self.cache.tuned_info(spec).map(|info| info.plan.fused(t))
                } else {
                    None
                }
            }
            KernelMethod::Oracle | KernelMethod::Taps => None,
        };
        let plan_label = tune_plan
            .as_ref()
            .map(|p| p.label(spec.dims))
            .unwrap_or_else(|| method.to_string());
        audit::global().observe(
            &spec.to_string(),
            n,
            &plan_label,
            machine_fingerprint(),
            || {
                let p = tune_plan?;
                let e = cost::estimate(&SimConfig::default(), spec, n, &p).ok()?;
                Some((e.cycles_per_point, e.mem_per_point))
            },
            measured_seconds,
            point_steps,
        );
    }
}

/// The machine fingerprint audit observations are keyed by (the default
/// §5.1 simulated machine every host kernel is compiled against),
/// computed once per process.
fn machine_fingerprint() -> &'static str {
    static FP: OnceLock<String> = OnceLock::new();
    FP.get_or_init(|| SimConfig::default().fingerprint())
}

/// Fold one evolution's per-shard kernel nanoseconds into the live
/// registry: a `stencil_shard_kernel_seconds{shard="..."}` gauge per
/// shard and the `stencil_shard_imbalance` gauge (max/mean shard kernel
/// time — 1.0 is perfectly balanced, 2.0 means the slowest shard worked
/// twice the average). Returns the imbalance ratio (0.0 when there was
/// no measurable work).
pub fn record_shard_times(nanos: &[u64]) -> f64 {
    let secs: Vec<f64> = nanos.iter().map(|&ns| ns as f64 / 1e9).collect();
    let max = secs.iter().cloned().fold(0.0f64, f64::max);
    if secs.is_empty() || max == 0.0 {
        return 0.0;
    }
    let mean = secs.iter().sum::<f64>() / secs.len() as f64;
    let r = registry::global();
    for (s, &v) in secs.iter().enumerate() {
        r.gauge_with("stencil_shard_kernel_seconds", &format!("shard=\"{s}\"")).set(v);
    }
    let imbalance = max / mean;
    r.gauge("stencil_shard_imbalance").set(imbalance);
    imbalance
}

/// Fusion accounting of one sharded evolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FuseReport {
    /// Effective time-tile depth `T` (after capping against shard
    /// starvation and the requested step count).
    pub fuse_steps: usize,
    /// Halo-exchange rounds performed (`ceil(steps / T) - 1` for
    /// multi-shard runs, 0 otherwise).
    pub halo_exchanges: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taps_kernel_matches_oracle_bitwise() {
        for spec in [
            StencilSpec::box2d(1),
            StencilSpec::star2d(2),
            StencilSpec::diag2d(1),
            StencilSpec::box3d(1),
            StencilSpec::star3d(2),
        ] {
            let shape: Vec<usize> = vec![4 * spec.order + 3; spec.dims];
            let a = DenseGrid::verification_input(&shape, 13);
            let key = PlanKey::single(spec, shape.clone(), KernelMethod::Taps);
            let plan = CompiledPlan::compile(key);
            let want = reference::apply(&CoeffTensor::paper_default(spec), &a);
            assert_eq!(plan.apply(&a), want, "{spec}");
        }
    }

    #[test]
    fn degenerate_tile_is_identity() {
        let spec = StencilSpec::box2d(2);
        // 4 rows = 2r: no interior row, must be a pure copy
        let a = DenseGrid::verification_input(&[4, 9], 1);
        for method in [KernelMethod::Oracle, KernelMethod::Taps, KernelMethod::Outer] {
            let plan =
                CompiledPlan::compile(PlanKey::single(spec, vec![4, 9], method));
            assert_eq!(plan.apply(&a), a, "{method}");
        }
    }

    #[test]
    fn lru_cache_hits_and_evicts() {
        let cache = PlanCache::new(2);
        let key = |n: usize| PlanKey::single(StencilSpec::box2d(1), vec![n, n], KernelMethod::Taps);
        let a = cache.get(key(8));
        let _b = cache.get(key(9));
        assert_eq!(cache.stats().misses, 2);
        // hit keeps 8 recent
        let a2 = cache.get(key(8));
        assert!(Arc::ptr_eq(&a, &a2));
        assert_eq!(cache.stats().hits, 1);
        // third key evicts the LRU entry (9)
        let _c = cache.get(key(10));
        let st = cache.stats();
        assert_eq!((st.evictions, st.len), (1, 2));
        // 9 was evicted → miss again (which in turn evicts 8, now LRU)
        cache.get(key(9));
        let st = cache.stats();
        assert_eq!(st.misses, 4);
        assert_eq!(st.evictions, 2);
        // 10 is still resident → hit
        cache.get(key(10));
        assert_eq!(cache.stats().hits, 2);
    }

    #[test]
    fn sharded_evolve_matches_reference_bitwise() {
        let spec = StencilSpec::box2d(1);
        let grid = DenseGrid::verification_input(&[24, 18], 0xC0FFEE);
        let coeffs = CoeffTensor::paper_default(spec);
        let want = reference::evolve(&coeffs, &grid, 3);
        for workers in [1usize, 4] {
            let ev = ShardedEvolver::new(workers);
            for shards in [1usize, 2, 5] {
                for method in [KernelMethod::Oracle, KernelMethod::Taps] {
                    let got = ev.evolve(spec, &grid, 3, shards, method).unwrap();
                    assert_eq!(got, want, "workers={workers} shards={shards} {method}");
                }
            }
        }
    }

    #[test]
    fn evolve_rejects_mismatched_grid() {
        let ev = ShardedEvolver::new(1);
        let g2 = DenseGrid::verification_input(&[8, 8], 0);
        assert!(ev
            .evolve(StencilSpec::box3d(1), &g2, 1, 2, KernelMethod::Taps)
            .is_err());
        let tiny = DenseGrid::verification_input(&[4, 4], 0);
        assert!(ev
            .evolve(StencilSpec::box2d(2), &tiny, 1, 1, KernelMethod::Taps)
            .is_err());
    }

    #[test]
    fn zero_steps_is_identity() {
        let ev = ShardedEvolver::new(2);
        let g = DenseGrid::verification_input(&[9, 9], 4);
        let out = ev
            .evolve(StencilSpec::box2d(1), &g, 0, 3, KernelMethod::Taps)
            .unwrap();
        assert_eq!(out, g);
    }

    #[test]
    fn kernel_method_parses_tuned() {
        assert_eq!("tuned".parse::<KernelMethod>().unwrap(), KernelMethod::Tuned);
        assert_eq!(KernelMethod::Tuned.to_string(), "tuned");
        assert_eq!("outer".parse::<KernelMethod>().unwrap(), KernelMethod::Outer);
        assert_eq!("kir".parse::<KernelMethod>().unwrap(), KernelMethod::Outer);
        assert_eq!(KernelMethod::Outer.to_string(), "outer");
        assert!("warp".parse::<KernelMethod>().is_err());
    }

    #[test]
    fn outer_kernel_runs_the_kir_host_program() {
        for spec in [StencilSpec::box2d(1), StencilSpec::star2d(2), StencilSpec::box3d(1)] {
            let shape: Vec<usize> = vec![4 * spec.order + 5; spec.dims];
            let a = DenseGrid::verification_input(&shape, 21);
            let plan = CompiledPlan::compile(PlanKey::single(
                spec,
                shape.clone(),
                KernelMethod::Outer,
            ));
            assert!(plan.host_ops().unwrap() > 0, "{spec}: host kernel compiled");
            let got = plan.apply(&a);
            let want = reference::apply(&CoeffTensor::paper_default(spec), &a);
            let err = got.max_abs_diff_interior(&want, 0);
            assert!(err < 1e-9, "{spec}: max err {err:e}");
            // boundary band is copied bitwise, like every serve kernel
            assert_eq!(got.data[0], a.data[0]);
        }
        // taps/oracle plans never carry a host kernel
        let t = CompiledPlan::compile(PlanKey::single(
            StencilSpec::box2d(1),
            vec![10, 10],
            KernelMethod::Taps,
        ));
        assert!(t.host_ops().is_none());
    }

    #[test]
    fn cache_engine_selects_host_execution_engine() {
        let spec = StencilSpec::box2d(1);
        let shape = vec![13usize, 13];
        let a = DenseGrid::verification_input(&shape, 5);
        let mut interp_cache = PlanCache::new(4);
        interp_cache.set_engine(Engine::Interpret);
        assert_eq!(interp_cache.engine(), Engine::Interpret);
        let compiled_cache = PlanCache::new(4);
        assert_eq!(compiled_cache.engine(), Engine::Compiled);
        let mut simd_cache = PlanCache::new(4);
        simd_cache.set_engine(Engine::Simd);
        assert_eq!(simd_cache.engine(), Engine::Simd);
        let key = PlanKey::single(spec, shape.clone(), KernelMethod::Outer);
        let pi = interp_cache.get(key.clone());
        let pc = compiled_cache.get(key.clone());
        let ps = simd_cache.get(key);
        assert_eq!(pi.host_engine(), Some(Engine::Interpret));
        assert_eq!(pc.host_engine(), Some(Engine::Compiled));
        assert_eq!(ps.host_engine(), Some(Engine::Simd));
        // all engines, any thread budget: bitwise identical tiles
        let want = pi.apply(&a);
        assert_eq!(pc.apply(&a), want);
        assert_eq!(pc.apply_with(&a, 4), want);
        assert_eq!(pc.apply_with(&a, 0), want);
        assert_eq!(ps.apply(&a), want);
        assert_eq!(ps.apply_with(&a, 4), want);
    }

    #[test]
    fn fused_plans_are_bitwise_repeated_single_applications() {
        for method in [KernelMethod::Oracle, KernelMethod::Taps, KernelMethod::Outer] {
            for (spec, shape) in [
                (StencilSpec::box2d(1), vec![14usize, 23]),
                (StencilSpec::star2d(2), vec![17, 12]),
                (StencilSpec::box3d(1), vec![9, 12, 10]),
            ] {
                let a = DenseGrid::verification_input(&shape, 31);
                let single =
                    CompiledPlan::compile(PlanKey::single(spec, shape.clone(), method));
                for t in [2usize, 3] {
                    let fused = CompiledPlan::compile(PlanKey {
                        spec,
                        shape: shape.clone(),
                        method,
                        steps: t,
                    });
                    let mut want = a.clone();
                    for _ in 0..t {
                        want = single.apply(&want);
                    }
                    assert_eq!(fused.apply(&a), want, "{spec} {method} T={t}");
                    assert_eq!(fused.apply_with(&a, 4), want, "{spec} {method} T={t} threaded");
                }
            }
        }
        // a fused outer plan carries a fused host kernel
        let fused = CompiledPlan::compile(PlanKey {
            spec: StencilSpec::box2d(1),
            shape: vec![14, 14],
            method: KernelMethod::Outer,
            steps: 4,
        });
        assert_eq!(fused.host_label(), Some("p-j8-t4"));
    }

    #[test]
    fn evolve_fused_matches_unfused_bitwise_and_counts_exchanges() {
        let ev = ShardedEvolver::new(3);
        for (spec, shape, steps) in [
            (StencilSpec::box2d(1), vec![32usize, 18], 8usize),
            (StencilSpec::star2d(2), vec![24, 20], 5),
        ] {
            let grid = DenseGrid::verification_input(&shape, 0xFEED);
            let want = reference::evolve(&CoeffTensor::paper_default(spec), &grid, steps);
            for method in [KernelMethod::Taps, KernelMethod::Outer] {
                let (unfused, shards_used, fr1) = ev
                    .evolve_fused(spec, &grid, steps, 3, method, 1)
                    .unwrap();
                assert_eq!(fr1, FuseReport { fuse_steps: 1, halo_exchanges: steps - 1 });
                for fuse in [2usize, 4] {
                    let (fused, shards_f, fr) = ev
                        .evolve_fused(spec, &grid, steps, 3, method, fuse)
                        .unwrap();
                    assert_eq!(
                        fused, unfused,
                        "{spec} {method} fuse={fuse}: fused diverged bitwise"
                    );
                    assert!(fr.fuse_steps >= 1 && fr.fuse_steps <= fuse);
                    if shards_f > 1 {
                        assert_eq!(
                            fr.halo_exchanges,
                            steps.div_ceil(fr.fuse_steps) - 1,
                            "{spec} {method} fuse={fuse}"
                        );
                    }
                    assert!(fr.halo_exchanges < fr1.halo_exchanges || fr.fuse_steps == 1);
                }
                if method == KernelMethod::Taps {
                    assert_eq!(unfused, want, "{spec}: unfused taps vs oracle");
                }
                assert!(shards_used >= 1);
            }
        }
    }

    #[test]
    fn shard_time_recording_computes_imbalance() {
        // induced skew: one shard worked 4 ms, two worked 1 ms →
        // max/mean = 4 / 2 = 2.0
        let imb = record_shard_times(&[4_000_000, 1_000_000, 1_000_000]);
        assert!((imb - 2.0).abs() < 1e-12, "{imb}");
        // perfectly balanced shards sit at 1.0
        let bal = record_shard_times(&[5_000, 5_000]);
        assert!((bal - 1.0).abs() < 1e-12, "{bal}");
        // nothing measurable: no verdict, gauge untouched
        assert_eq!(record_shard_times(&[]), 0.0);
        assert_eq!(record_shard_times(&[0, 0]), 0.0);
        // the per-shard gauges exist in the exposition (value raced by
        // concurrent evolutions, so only presence is asserted)
        let text = registry::global().render();
        assert!(text.contains("stencil_shard_kernel_seconds{shard=\"0\"}"), "{text}");
        assert!(text.contains("stencil_shard_imbalance"), "{text}");
    }

    #[test]
    fn fused_evolution_feeds_the_cost_audit() {
        let spec = StencilSpec::box2d(1);
        let ev = ShardedEvolver::new(2);
        let grid = DenseGrid::verification_input(&[20, 20], 77);
        ev.evolve_fused(spec, &grid, 2, 2, KernelMethod::Outer, 1).unwrap();
        let snap = audit::global().snapshot();
        let entry = snap
            .iter()
            .find(|k| k.spec == spec.to_string() && k.n == 18)
            .expect("outer evolution audited");
        assert!(entry.predicted_cycles_per_point > 0.0);
        assert!(entry.count >= 1);
        assert!(entry.mean_s_per_pt > 0.0);
        // taps runs are unmodelled and never audited
        ev.evolve_fused(spec, &grid, 2, 2, KernelMethod::Taps, 1).unwrap();
        let snap = audit::global().snapshot();
        assert!(
            !snap.iter().any(|k| k.plan == "taps"),
            "taps must not be audited: {snap:?}"
        );
    }

    #[test]
    fn tuned_kernel_is_bitwise_taps() {
        let spec = StencilSpec::star2d(2);
        let shape = vec![13, 13];
        let a = DenseGrid::verification_input(&shape, 9);
        let t = CompiledPlan::compile(PlanKey::single(spec, shape.clone(), KernelMethod::Taps));
        let u = CompiledPlan::compile(PlanKey::single(spec, shape, KernelMethod::Tuned));
        assert_eq!(t.apply(&a), u.apply(&a));
        assert!(u.tuned.is_none()); // compile() alone never consults a DB
    }

    #[test]
    fn cache_attaches_tuning_db_entries_to_tuned_plans() {
        use crate::tune::{tune, Strategy, TuneDb};
        use crate::sim::SimConfig;

        let cfg = SimConfig::default();
        let spec = StencilSpec::box2d(1);
        let mut db = TuneDb::new();
        let out = tune(&cfg, spec, 16, 2, Strategy::CostGuided).unwrap();
        db.record(&out);
        let cache = PlanCache::with_tune_db(4, Arc::new(db), cfg.fingerprint());

        let tuned = cache.get(PlanKey::single(spec, vec![10, 10], KernelMethod::Tuned));
        let info = tuned.tuned.as_ref().expect("tuned plan carries the DB entry");
        assert_eq!(info.label, out.best().plan.label(spec.dims));
        assert_eq!(info.plan, out.best().plan);
        assert_eq!(info.tuned_n, 16);
        // a supported tuned plan compiles to a real host kernel;
        // grid-restructuring plans fall back to the bitwise taps kernel
        match info.plan.to_method() {
            Method::Outer(_) | Method::AutoVec | Method::Scalar => {
                assert!(tuned.host_ops().unwrap() > 0, "tuned plan compiled to a host kernel");
                assert!(tuned.host_label().is_some());
            }
            Method::Dlt | Method::Tv => assert!(tuned.host_ops().is_none()),
        }
        assert_eq!(cache.tuned_label(spec), Some(info.label.clone()));
        // serving adopts the winner's time-tile depth for tuned requests
        assert_eq!(cache.tuned_fuse(spec), info.plan.steps.max(1));
        assert_eq!(cache.stats().tuned_hits, 1);

        // plain taps plans never consult the database
        let taps = cache.get(PlanKey::single(spec, vec![10, 10], KernelMethod::Taps));
        assert!(taps.tuned.is_none());
        assert_eq!(cache.stats().tuned_hits, 1);
        // a spec the DB has no entry for compiles fine, unannotated
        let other = cache.get(PlanKey::single(StencilSpec::star3d(1), vec![6, 6, 6], KernelMethod::Tuned));
        assert!(other.tuned.is_none());
        assert_eq!(cache.tuned_label(StencilSpec::star3d(1)), None);
        assert_eq!(cache.tuned_fuse(StencilSpec::star3d(1)), 1);
    }
}
