//! Domain decomposition: split a [`DenseGrid`] into contiguous slabs along
//! the outermost (slowest-varying) dimension, each padded with ghost rows
//! sized by the stencil order.
//!
//! Slab decomposition keeps every per-shard tile a dense row-major grid —
//! a "row" here is one index of dimension 0 (a line in 2D, a plane in 3D),
//! always a contiguous `shape[1..].product()` run of the storage — so
//! extraction, halo exchange, and assembly are all `memcpy`-shaped.
//!
//! **Exactness.** Every shard's height is kept `>= halo` (the shard count
//! is clamped if needed). With ghosts of depth `halo = order` refreshed
//! between steps, applying the scalar oracle per tile reproduces the
//! global computation *bitwise*: tile-interior points see exactly the
//! neighbourhood the global sweep sees, and the global frozen-boundary
//! band (distance `< order` from a global edge) is always a tile-boundary
//! band too, so it is copied, never computed. See `serve::halo` for the
//! exchange and the proof-by-test.

use crate::stencil::DenseGrid;

/// One shard's slab: owned rows `[lo, hi)` of dimension 0, plus ghost
/// depths actually present on each side (`min(halo, space available)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slab {
    /// First owned row (global index along dimension 0).
    pub lo: usize,
    /// One past the last owned row.
    pub hi: usize,
    /// Ghost rows below `lo` in this shard's tile (0 for the first shard).
    pub ghost_lo: usize,
    /// Ghost rows above `hi` in this shard's tile (0 for the last shard).
    pub ghost_hi: usize,
}

impl Slab {
    /// Owned rows.
    pub fn rows(&self) -> usize {
        self.hi - self.lo
    }

    /// Total tile rows including ghosts.
    pub fn tile_rows(&self) -> usize {
        self.ghost_lo + self.rows() + self.ghost_hi
    }
}

/// A slab decomposition of a grid shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    /// Global grid shape.
    pub shape: Vec<usize>,
    /// Ghost depth (the stencil order `r`).
    pub halo: usize,
    /// Per-shard slabs, in order along dimension 0.
    pub slabs: Vec<Slab>,
}

impl Partition {
    /// Largest shard count such that every shard still owns `>= halo`
    /// rows (required for single-neighbour halo exchange and for the
    /// frozen-boundary band to stay within the edge shards).
    pub fn max_shards(n0: usize, halo: usize) -> usize {
        (n0 / halo.max(1)).max(1)
    }

    /// Largest time-tile depth `t <= fuse` whose deep halo
    /// (`order * t`) still admits the shard count the caller wants:
    /// fusing `t` steps behind ghosts of depth `order * t` lets a shard
    /// run `t` steps between exchanges, but every shard must own
    /// `>= order * t` rows, so deep halos shrink
    /// [`Partition::max_shards`]. The returned depth never starves the
    /// decomposition below `min(want_shards, max_shards(n0, order))` —
    /// shard-level parallelism wins over exchange amortization.
    pub fn max_fuse(n0: usize, order: usize, want_shards: usize, fuse: usize) -> usize {
        let want = want_shards.max(1).min(Self::max_shards(n0, order));
        let mut t = fuse.max(1);
        while t > 1 && Self::max_shards(n0, order * t) < want {
            t -= 1;
        }
        t
    }

    /// Balanced decomposition of `shape` into (up to) `shards` slabs.
    ///
    /// The effective shard count is clamped to [`Partition::max_shards`];
    /// remainder rows go to the leading shards, so heights differ by at
    /// most one (the "uneven shards" the scheduler's work stealing evens
    /// out).
    pub fn new(shape: &[usize], shards: usize, halo: usize) -> anyhow::Result<Partition> {
        anyhow::ensure!(
            shape.len() == 2 || shape.len() == 3,
            "grids are 2D or 3D, got shape {shape:?}"
        );
        anyhow::ensure!(halo >= 1, "halo (stencil order) must be >= 1");
        let n0 = shape[0];
        anyhow::ensure!(n0 >= 1, "empty leading dimension");
        let s = shards.max(1).min(Self::max_shards(n0, halo));
        let base = n0 / s;
        let rem = n0 % s;
        let mut slabs = Vec::with_capacity(s);
        let mut lo = 0usize;
        for i in 0..s {
            let height = base + usize::from(i < rem);
            let hi = lo + height;
            slabs.push(Slab {
                lo,
                hi,
                ghost_lo: halo.min(lo),
                ghost_hi: halo.min(n0 - hi),
            });
            lo = hi;
        }
        debug_assert_eq!(lo, n0);
        Ok(Partition { shape: shape.to_vec(), halo, slabs })
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.slabs.len()
    }

    /// True when there are no slabs. Never the case for a constructed
    /// partition (`new` always produces at least one shard); present for
    /// API completeness alongside [`Partition::len`].
    pub fn is_empty(&self) -> bool {
        self.slabs.is_empty()
    }

    /// Elements per row of dimension 0 (`shape[1..].product()`).
    pub fn row_elems(&self) -> usize {
        self.shape[1..].iter().product()
    }

    /// Storage shape of shard `s`'s tile.
    pub fn tile_shape(&self, s: usize) -> Vec<usize> {
        let mut shape = self.shape.clone();
        shape[0] = self.slabs[s].tile_rows();
        shape
    }

    /// Extract all tiles (owned rows plus current ghost rows) from a grid.
    pub fn extract(&self, grid: &DenseGrid) -> Vec<DenseGrid> {
        assert_eq!(grid.shape, self.shape, "grid does not match partition");
        let rest = self.row_elems();
        self.slabs
            .iter()
            .enumerate()
            .map(|(s, slab)| {
                let start = (slab.lo - slab.ghost_lo) * rest;
                let len = slab.tile_rows() * rest;
                DenseGrid {
                    shape: self.tile_shape(s),
                    data: grid.data[start..start + len].to_vec(),
                }
            })
            .collect()
    }

    /// Reassemble a global grid from each shard's *owned* rows (ghost rows
    /// are discarded).
    pub fn assemble(&self, tiles: &[&DenseGrid]) -> anyhow::Result<DenseGrid> {
        anyhow::ensure!(
            tiles.len() == self.slabs.len(),
            "expected {} tiles, got {}",
            self.slabs.len(),
            tiles.len()
        );
        let rest = self.row_elems();
        let mut out = DenseGrid::zeros(&self.shape);
        for (s, (slab, tile)) in self.slabs.iter().zip(tiles).enumerate() {
            anyhow::ensure!(
                tile.shape == self.tile_shape(s),
                "tile {s} shape {:?} does not match partition {:?}",
                tile.shape,
                self.tile_shape(s)
            );
            let src = slab.ghost_lo * rest;
            let dst = slab.lo * rest;
            let len = slab.rows() * rest;
            out.data[dst..dst + len].copy_from_slice(&tile.data[src..src + len]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_heights_cover_domain() {
        let p = Partition::new(&[17, 9], 4, 2).unwrap();
        assert_eq!(p.len(), 4);
        let heights: Vec<usize> = p.slabs.iter().map(Slab::rows).collect();
        assert_eq!(heights.iter().sum::<usize>(), 17);
        assert!(heights.iter().all(|&h| h >= 2));
        assert!(heights.iter().max().unwrap() - heights.iter().min().unwrap() <= 1);
        // contiguity
        for w in p.slabs.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
        }
        assert_eq!(p.slabs[0].lo, 0);
        assert_eq!(p.slabs.last().unwrap().hi, 17);
    }

    #[test]
    fn ghost_depths() {
        let p = Partition::new(&[12, 8], 3, 2).unwrap();
        assert_eq!(p.slabs[0].ghost_lo, 0);
        assert_eq!(p.slabs[0].ghost_hi, 2);
        assert_eq!(p.slabs[1].ghost_lo, 2);
        assert_eq!(p.slabs[1].ghost_hi, 2);
        assert_eq!(p.slabs[2].ghost_lo, 2);
        assert_eq!(p.slabs[2].ghost_hi, 0);
    }

    #[test]
    fn shard_count_clamps_to_min_height() {
        // 10 rows with halo 3 can host at most 3 shards of height >= 3
        let p = Partition::new(&[10, 6], 64, 3).unwrap();
        assert_eq!(p.len(), 3);
        assert!(p.slabs.iter().all(|s| s.rows() >= 3));
        // single row always yields one shard
        let p1 = Partition::new(&[1, 6], 8, 1).unwrap();
        assert_eq!(p1.len(), 1);
    }

    #[test]
    fn max_fuse_caps_deep_halos_against_shard_starvation() {
        // 64 rows, order 1, 4 shards wanted: halo 4 still hosts 16 shards
        assert_eq!(Partition::max_fuse(64, 1, 4, 4), 4);
        // 16 rows, order 2, 4 shards wanted: halo 2·4=8 would allow only
        // 2 shards → fuse backs off to T=2 (halo 4, 4 shards)
        assert_eq!(Partition::max_fuse(16, 2, 4, 4), 2);
        // a single-shard request never needs to back off
        assert_eq!(Partition::max_fuse(16, 2, 1, 8), 8);
        // asking for more shards than even T=1 admits caps the want first
        assert_eq!(Partition::max_fuse(8, 2, 64, 4), 1);
        assert_eq!(Partition::max_fuse(8, 2, 64, 1), 1);
        // and T=0 means T=1
        assert_eq!(Partition::max_fuse(64, 1, 2, 0), 1);
    }

    #[test]
    fn deep_halo_partitions_host_fused_ghost_bands() {
        // halo = order * T: the partition clamps shard counts the same
        // way, and every shard's ghost band is T·r deep (or runs to the
        // global edge)
        let p = Partition::new(&[32, 8], 4, 2 * 3).unwrap();
        assert!(p.len() <= Partition::max_shards(32, 6));
        for s in p.slabs.iter() {
            assert!(s.rows() >= 6);
            assert!(s.ghost_lo == 6 || s.lo == 0);
            assert!(s.ghost_hi == 6 || s.hi == 32);
        }
    }

    #[test]
    fn extract_assemble_roundtrip() {
        for shape in [vec![13usize, 7], vec![6, 5, 4]] {
            let g = DenseGrid::verification_input(&shape, 3);
            for shards in [1usize, 2, 3, 5] {
                let p = Partition::new(&shape, shards, 1).unwrap();
                let tiles = p.extract(&g);
                let refs: Vec<&DenseGrid> = tiles.iter().collect();
                assert_eq!(p.assemble(&refs).unwrap(), g, "{shape:?} x{shards}");
            }
        }
    }

    #[test]
    fn tiles_carry_ghost_rows() {
        let g = DenseGrid::verification_input(&[9, 4], 5);
        let p = Partition::new(&[9, 4], 3, 1).unwrap();
        let tiles = p.extract(&g);
        // middle shard: rows [3,6) plus one ghost row each side = rows [2,7)
        assert_eq!(tiles[1].shape, vec![5, 4]);
        assert_eq!(tiles[1].data[..4], g.data[2 * 4..3 * 4]);
        assert_eq!(tiles[1].data[4 * 4..], g.data[6 * 4..7 * 4]);
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(Partition::new(&[8], 2, 1).is_err());
        assert!(Partition::new(&[8, 8], 2, 0).is_err());
    }
}
