//! Disassembly and utilization analysis.
//!
//! The paper ships an automatic code generator (§4.4); this module renders
//! generated programs in an SME-like assembly syntax (for inspection and
//! for the `disasm` CLI command) and derives occupancy/roofline summaries
//! from run statistics.

use super::config::SimConfig;
use super::isa::{Instr, Program};
use super::stats::RunStats;
use std::fmt::Write as _;

/// Render one instruction in SME-like assembly.
pub fn disasm(i: &Instr) -> String {
    match *i {
        Instr::LdVec { dst, addr } => format!("ld1d    {dst}, [{addr}]"),
        Instr::StVec { src, addr } => format!("st1d    {src}, [{addr}]"),
        Instr::LdVecStrided { dst, base, stride } => {
            format!("ld1d    {dst}, [{base}, gather +{stride}]")
        }
        Instr::LdSplat { dst, addr } => format!("ld1rd   {dst}, [{addr}]"),
        Instr::StLane { src, lane, addr } => format!("st1d    {src}[{lane}], [{addr}]"),
        Instr::Ext { dst, lo, hi, shift } => format!("ext     {dst}, {lo}, {hi}, #{shift}"),
        Instr::Dup { dst, src, lane } => format!("dup     {dst}, {src}[{lane}]"),
        Instr::VFma { acc, a, b } => format!("fmla    {acc}, {a}, {b}"),
        Instr::VFmaLane { acc, a, b, lane } => format!("fmla    {acc}, {a}, {b}[{lane}]"),
        Instr::VAdd { dst, a, b } => format!("fadd    {dst}, {a}, {b}"),
        Instr::VMul { dst, a, b } => format!("fmul    {dst}, {a}, {b}"),
        Instr::VZero { dst } => format!("dup     {dst}, #0"),
        Instr::MZero { m } => format!("zero    {m}"),
        Instr::Fmopa { m, a, b } => format!("fmopa   {m}, {a}, {b}"),
        Instr::MovVToMRow { m, row, src } => format!("mova    {m}h[{row}], {src}"),
        Instr::MovMRowToV { dst, m, row } => format!("mova    {dst}, {m}h[{row}]"),
        Instr::MovVToMCol { m, col, src } => format!("mova    {m}v[{col}], {src}"),
        Instr::MovMColToV { dst, m, col } => format!("mova    {dst}, {m}v[{col}]"),
        Instr::LdMRow { m, row, addr } => format!("ld1d    {m}h[{row}], [{addr}]"),
        Instr::StMRow { m, row, addr } => format!("st1d    {m}h[{row}], [{addr}]"),
    }
}

/// Disassemble (up to) the first `limit` instructions of a program.
pub fn disassemble(p: &Program, limit: usize) -> String {
    let mut out = String::new();
    for (pc, i) in p.0.iter().take(limit).enumerate() {
        let _ = writeln!(out, "{pc:6}: {}", disasm(i));
    }
    if p.0.len() > limit {
        let _ = writeln!(out, "  ... ({} more)", p.0.len() - limit);
    }
    out
}

/// What bounds a run, derived from its counters and the machine config.
#[derive(Debug, Clone, PartialEq)]
pub struct Roofline {
    /// Cycles the outer-product unit was occupied (1/FMOPA issue).
    pub opu_cycles: u64,
    /// Cycles the vector ALUs were occupied (÷ `valu_units`).
    pub valu_cycles: u64,
    /// Cycles the LSUs were occupied (÷ `lsu_units`, incl. splits/gathers).
    pub lsu_cycles: u64,
    /// Cycles the DRAM channel was occupied (lines × interval).
    pub dram_cycles: u64,
    /// Measured cycles.
    pub cycles: u64,
    /// The dominating resource.
    pub bound: &'static str,
}

/// Derive the roofline decomposition of a finished run.
pub fn roofline(cfg: &SimConfig, stats: &RunStats) -> Roofline {
    let valu_ops = stats.count("fmla")
        + stats.count("fmla.idx")
        + stats.count("fadd")
        + stats.count("fmul")
        + stats.count("ext")
        + stats.count("dup")
        + stats.count("vzero")
        + stats.count("mova.h.in")
        + stats.count("mova.h.out")
        + stats.count("mova.v.in")
        + stats.count("mova.v.out");
    let lsu_ops = stats.count("ld1d")
        + stats.count("st1d")
        + stats.count("ld1rd")
        + stats.count("st1d.lane")
        + stats.count("ld1d.za")
        + stats.count("st1d.za")
        + stats.count("ld1d.gather") * cfg.vlen as u64;
    let opu_cycles = stats.count("fmopa") + stats.count("zero.za");
    let valu_cycles = valu_ops / cfg.valu_units as u64;
    let lsu_cycles = lsu_ops / cfg.lsu_units as u64;
    let dram_cycles = stats.cache.mem_accesses * cfg.cache.mem_line_interval;
    let bound = [
        ("OPU", opu_cycles),
        ("VALU", valu_cycles),
        ("LSU", lsu_cycles),
        ("DRAM", dram_cycles),
    ]
    .into_iter()
    .max_by_key(|&(_, c)| c)
    .map(|(n, _)| n)
    .unwrap();
    Roofline { opu_cycles, valu_cycles, lsu_cycles, dram_cycles, cycles: stats.cycles, bound }
}

impl std::fmt::Display for Roofline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "roofline: OPU {} | VALU {} | LSU {} | DRAM {} of {} cycles → {}-bound",
            self.opu_cycles, self.valu_cycles, self.lsu_cycles, self.dram_cycles, self.cycles,
            self.bound
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::isa::{MReg, Sink, VReg};

    #[test]
    fn disasm_syntax() {
        assert_eq!(
            disasm(&Instr::Fmopa { m: MReg(0), a: VReg(1), b: VReg(2) }),
            "fmopa   za0, z1, z2"
        );
        assert_eq!(
            disasm(&Instr::Ext { dst: VReg(3), lo: VReg(1), hi: VReg(2), shift: 5 }),
            "ext     z3, z1, z2, #5"
        );
        assert_eq!(disasm(&Instr::LdVec { dst: VReg(0), addr: 128 }), "ld1d    z0, [128]");
    }

    #[test]
    fn disassemble_truncates() {
        let mut p = Program::default();
        for k in 0..10u8 {
            p.emit(Instr::VZero { dst: VReg(k) });
        }
        let text = disassemble(&p, 4);
        assert_eq!(text.lines().count(), 5);
        assert!(text.contains("(6 more)"));
    }

    #[test]
    fn roofline_identifies_opu_bound() {
        let cfg = SimConfig::default();
        let mut stats = RunStats::default();
        stats.cycles = 100;
        stats.mix.insert("fmopa", 90);
        stats.mix.insert("ld1d", 10);
        let r = roofline(&cfg, &stats);
        assert_eq!(r.bound, "OPU");
        assert_eq!(r.opu_cycles, 90);
    }

    #[test]
    fn roofline_identifies_dram_bound() {
        let cfg = SimConfig::default();
        let mut stats = RunStats::default();
        stats.cycles = 5000;
        stats.mix.insert("fmla", 100);
        stats.cache.mem_accesses = 400; // × 12 = 4800 cycles
        let r = roofline(&cfg, &stats);
        assert_eq!(r.bound, "DRAM");
    }

    #[test]
    fn gather_counts_vlen_lsu_slots() {
        let cfg = SimConfig::default();
        let mut stats = RunStats::default();
        stats.mix.insert("ld1d.gather", 4);
        let r = roofline(&cfg, &stats);
        assert_eq!(r.lsu_cycles, 4 * 8 / 2);
    }
}
