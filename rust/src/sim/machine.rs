//! The machine: functional execution + cycle-approximate timing.
//!
//! Every instruction is executed *functionally* (real f64 values in the
//! register files and memory) and simultaneously *timed* by an in-order,
//! multi-issue scoreboard:
//!
//! - an instruction issues at the earliest cycle `>=` the previous
//!   instruction's issue cycle (in-order) where its source registers are
//!   ready, an execution-unit instance is free, and an issue slot remains;
//! - destination registers become ready `latency` cycles after issue
//!   (loads: the cache-model latency);
//! - cache misses occupy one of `mshrs` miss registers until data returns,
//!   bounding memory-level parallelism;
//! - back-to-back `FMOPA` to the same tile pipeline through accumulator
//!   forwarding (1-cycle RAW), but *reads* of a tile (row/col moves,
//!   stores) wait for the full `lat_fmopa` — mirroring how SME/MMA
//!   accumulators behave;
//! - vector FMA chains on one accumulator pay full latency (generators
//!   are expected to use multiple accumulators, as compilers do).

use super::cache::CacheSim;
use super::config::SimConfig;
use super::isa::{Instr, Sink};
#[cfg(test)]
use super::isa::VReg;
use super::stats::RunStats;

/// Execution-unit classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Unit {
    /// Load/store pipes.
    Lsu,
    /// Vector ALU pipes (FMA, EXT, moves).
    Valu,
    /// Outer-product unit(s).
    Opu,
}

/// The simulated machine. Implements [`Sink`], so code generators can emit
/// straight into it and programs are executed on-the-fly.
pub struct Machine {
    /// Machine parameters.
    pub cfg: SimConfig,
    /// Flat data memory (f64 elements).
    pub mem: Vec<f64>,
    next_alloc: usize,
    /// Flat vector register file (`n_vregs × vlen`).
    vregs: Vec<f64>,
    /// Flat matrix register file (`n_mregs × vlen²`).
    mregs: Vec<f64>,
    cache: CacheSim,
    // ---- timing state ----
    /// Instructions fetched so far (front-end bandwidth model).
    fetched: u64,
    unit_free: [Vec<u64>; 3],
    v_ready: Vec<u64>,
    /// Tile ready-for-read (full latency after last write).
    m_read_ready: Vec<u64>,
    /// Tile ready-for-accumulate (forwarding: issue + 1).
    m_accum_ready: Vec<u64>,
    mshr: Vec<u64>,
    /// Next cycle the DRAM channel can start another line transfer.
    mem_next_free: u64,
    end_cycle: u64,
    /// Cache counters at the last `finish()` (for per-run deltas).
    cache_snapshot: super::cache::CacheStats,
    /// Per-opcode counters (folded into `stats.mix` at `finish()`).
    mix_counts: [u64; super::isa::N_OPCODES],
    /// Reusable scratch vector (avoids per-instruction allocation).
    tmp: Vec<f64>,
    /// Counters for the current run.
    pub stats: RunStats,
}

impl Machine {
    /// Fresh machine with the given configuration.
    pub fn new(cfg: SimConfig) -> Self {
        let cache = CacheSim::new(&cfg.cache);
        Self {
            vregs: vec![0.0; cfg.vlen * cfg.n_vregs],
            mregs: vec![0.0; cfg.vlen * cfg.vlen * cfg.n_mregs],
            v_ready: vec![0; cfg.n_vregs],
            m_read_ready: vec![0; cfg.n_mregs],
            m_accum_ready: vec![0; cfg.n_mregs],
            unit_free: [
                vec![0; cfg.lsu_units],
                vec![0; cfg.valu_units],
                vec![0; cfg.opu_units],
            ],
            mem: Vec::new(),
            next_alloc: 0,
            tmp: vec![0.0; cfg.vlen.max(8)],
            cache,
            cfg,
            fetched: 0,
            mshr: Vec::new(),
            mem_next_free: 0,
            end_cycle: 0,
            cache_snapshot: super::cache::CacheStats::default(),
            mix_counts: [0; super::isa::N_OPCODES],
            stats: RunStats::default(),
        }
    }

    /// Allocate `n` f64 elements with a guard band on both sides (so halo
    /// reads just outside an array stay in mapped memory) and return the
    /// base element address.
    pub fn alloc(&mut self, n: usize) -> usize {
        const GUARD: usize = 64;
        // 64-byte-align every base (what a real allocator + posix_memalign
        // would give a performance-conscious stencil code).
        let base = (self.next_alloc + GUARD).div_ceil(self.cfg.vlen) * self.cfg.vlen;
        self.next_alloc = base + n + GUARD;
        if self.mem.len() < self.next_alloc {
            self.mem.resize(self.next_alloc, 0.0);
        }
        base
    }

    /// Copy a slice into memory at `addr`.
    pub fn write_mem(&mut self, addr: usize, data: &[f64]) {
        self.mem[addr..addr + data.len()].copy_from_slice(data);
    }

    /// Read `n` elements from memory at `addr`.
    pub fn read_mem(&self, addr: usize, n: usize) -> &[f64] {
        &self.mem[addr..addr + n]
    }

    /// Finish the run: return the stats with `cycles` set to the cycle at
    /// which the last result/store completes, and reset the timing state
    /// (memory and caches keep their contents).
    pub fn finish(&mut self) -> RunStats {
        self.stats.cycles = self
            .end_cycle
            .max(self.fetched / self.cfg.issue_width as u64);
        // per-run cache counters = delta since the previous finish()
        let cur = &self.cache.stats;
        let snap = &self.cache_snapshot;
        self.stats.cache = super::cache::CacheStats {
            l1_hits: cur.l1_hits - snap.l1_hits,
            l2_hits: cur.l2_hits - snap.l2_hits,
            mem_accesses: cur.mem_accesses - snap.mem_accesses,
            l1_fill_bytes: cur.l1_fill_bytes - snap.l1_fill_bytes,
            l2_fill_bytes: cur.l2_fill_bytes - snap.l2_fill_bytes,
            writeback_bytes: cur.writeback_bytes - snap.writeback_bytes,
        };
        self.cache_snapshot = cur.clone();
        for (op, &count) in self.mix_counts.iter().enumerate() {
            if count > 0 {
                *self
                    .stats
                    .mix
                    .entry(super::isa::OPCODE_MNEMONICS[op])
                    .or_insert(0) += count;
            }
        }
        self.mix_counts = [0; super::isa::N_OPCODES];
        let out = std::mem::take(&mut self.stats);
        self.fetched = 0;
        self.end_cycle = 0;
        self.mem_next_free = 0;
        self.mshr.clear();
        for v in &mut self.v_ready {
            *v = 0;
        }
        for v in &mut self.m_read_ready {
            *v = 0;
        }
        for v in &mut self.m_accum_ready {
            *v = 0;
        }
        for u in &mut self.unit_free {
            for c in u.iter_mut() {
                *c = 0;
            }
        }
        out
    }

    /// Drop all cache contents (cold-start the next run) without touching
    /// memory values.
    pub fn flush_caches(&mut self) {
        self.cache = CacheSim::new(&self.cfg.cache);
    }

    // ---------------- timing helpers ----------------

    /// Issue an instruction: find the issue cycle given operand readiness,
    /// front-end fetch bandwidth and unit availability.
    ///
    /// Models an out-of-order core (the Kunpeng-920-class core of §5.1)
    /// with an in-order front end fetching `issue_width` instructions per
    /// cycle and an effectively unbounded window: an instruction executes
    /// as soon as its operands are ready and a unit instance is free; a
    /// stalled instruction does not block independent younger ones.
    fn issue(&mut self, unit: Unit, ready: u64) -> u64 {
        self.fetched += 1;
        let floor = self.fetched / self.cfg.issue_width as u64;
        let ui = unit as usize;
        // earliest unit instance
        let (best, &free) = self.unit_free[ui]
            .iter()
            .enumerate()
            .min_by_key(|(_, &c)| c)
            .expect("unit instance");
        let t = ready.max(free).max(floor);
        // fully pipelined units: occupied for 1 cycle
        self.unit_free[ui][best] = t + 1;
        t
    }

    /// Account a memory access of `len` elements at element address `addr`.
    /// Returns the data-ready cycle given issue at `t`.
    fn mem_access(&mut self, t: u64, addr: usize, elems: usize, write: bool) -> u64 {
        let byte = (addr as u64) * 8;
        let len = (elems as u64) * 8;
        let (lat, lines, mem_lines) = self.cache.access_range(byte, len, write);
        let mut extra = 0;
        if lines > 1 {
            extra += self.cfg.split_line_penalty * (lines - 1);
            // a split access occupies the LSU one extra cycle per extra
            // line (real cores replay the second half)
            let ui = Unit::Lsu as usize;
            if let Some(slot) = self.unit_free[ui].iter_mut().min() {
                *slot += lines - 1;
            }
        }
        // MSHR pressure for anything that missed L1
        let mut t = t;
        if lat > self.cfg.cache.lat_l1 {
            self.mshr.retain(|&c| c > t);
            if self.mshr.len() >= self.cfg.mshrs {
                let earliest = *self.mshr.iter().min().unwrap();
                self.stats.mshr_stall_cycles += earliest - t;
                t = earliest;
                self.mshr.retain(|&c| c > t);
            }
            self.mshr.push(t + lat);
        }
        let mut done = t + lat + extra;
        // DRAM bandwidth: every line that came from memory occupies the
        // channel for `mem_line_interval` cycles.
        if mem_lines > 0 {
            let interval = self.cfg.cache.mem_line_interval;
            self.mem_next_free = self.mem_next_free.max(t) + interval * mem_lines;
            done = done.max(self.mem_next_free);
        }
        done
    }

    fn retire(&mut self, done: u64) {
        if done > self.end_cycle {
            self.end_cycle = done;
        }
    }

    // ---------------- execute one instruction ----------------

    /// Execute `i` functionally and account its timing.
    pub fn exec(&mut self, i: &Instr) {
        self.stats.instructions += 1;
        self.stats.flops += i.flops(self.cfg.vlen);
        // §Perf: indexed counter (a BTreeMap<&str> entry per instruction
        // cost ~30% of the whole execute loop); folded into stats.mix at
        // finish().
        self.mix_counts[i.opcode() as usize] += 1;
        let vlen = self.cfg.vlen;
        match *i {
            Instr::LdVec { dst, addr } => {
                let t = self.issue(Unit::Lsu, 0);
                let done = self.mem_access(t, addr, vlen, false);
                for k in 0..vlen {
                    self.vregs[dst.0 as usize * vlen + k] = self.mem[addr + k];
                }
                self.v_ready[dst.0 as usize] = done;
                self.retire(done);
            }
            Instr::StVec { src, addr } => {
                let ready = self.v_ready[src.0 as usize];
                let t = self.issue(Unit::Lsu, ready);
                let done = self.mem_access(t, addr, vlen, true);
                for k in 0..vlen {
                    self.mem[addr + k] = self.vregs[src.0 as usize * vlen + k];
                }
                self.retire(done);
            }
            Instr::LdVecStrided { dst, base, stride } => {
                // gather: one access per element, occupies the LSU longer
                let mut t = self.issue(Unit::Lsu, 0);
                let mut done = t;
                for k in 0..vlen {
                    let a = base + k * stride;
                    let d = self.mem_access(t, a, 1, false);
                    done = done.max(d);
                    t += 1; // element-serialized
                    self.vregs[dst.0 as usize * vlen + k] = self.mem[a];
                }
                // keep the LSU busy for the serialized elements
                let ui = Unit::Lsu as usize;
                let idx = (0..self.unit_free[ui].len())
                    .min_by_key(|&x| self.unit_free[ui][x])
                    .unwrap();
                self.unit_free[ui][idx] = self.unit_free[ui][idx].max(t);
                self.v_ready[dst.0 as usize] = done;
                self.retire(done);
            }
            Instr::LdSplat { dst, addr } => {
                let t = self.issue(Unit::Lsu, 0);
                let done = self.mem_access(t, addr, 1, false);
                let v = self.mem[addr];
                self.vregs[dst.0 as usize * vlen..(dst.0 as usize + 1) * vlen].fill(v);
                self.v_ready[dst.0 as usize] = done;
                self.retire(done);
            }
            Instr::StLane { src, lane, addr } => {
                let ready = self.v_ready[src.0 as usize];
                let t = self.issue(Unit::Lsu, ready);
                let done = self.mem_access(t, addr, 1, true);
                self.mem[addr] = self.vregs[src.0 as usize * vlen + lane];
                self.retire(done);
            }
            Instr::Ext { dst, lo, hi, shift } => {
                debug_assert!(shift <= vlen);
                let ready = self.v_ready[lo.0 as usize].max(self.v_ready[hi.0 as usize]);
                let t = self.issue(Unit::Valu, ready);
                for k in 0..vlen {
                    let pos = k + shift;
                    self.tmp[k] = if pos < vlen {
                        self.vregs[lo.0 as usize * vlen + pos]
                    } else {
                        self.vregs[hi.0 as usize * vlen + pos - vlen]
                    };
                }
                let d0 = dst.0 as usize * vlen;
                self.vregs[d0..d0 + vlen].copy_from_slice(&self.tmp[..vlen]);
                self.v_ready[dst.0 as usize] = t + self.cfg.lat_ext;
                self.retire(t + self.cfg.lat_ext);
            }
            Instr::Dup { dst, src, lane } => {
                let ready = self.v_ready[src.0 as usize];
                let t = self.issue(Unit::Valu, ready);
                let v = self.vregs[src.0 as usize * vlen + lane];
                self.vregs[dst.0 as usize * vlen..(dst.0 as usize + 1) * vlen].fill(v);
                self.v_ready[dst.0 as usize] = t + self.cfg.lat_ext;
                self.retire(t + self.cfg.lat_ext);
            }
            Instr::VFma { acc, a, b } => {
                let ready = self.v_ready[acc.0 as usize]
                    .max(self.v_ready[a.0 as usize])
                    .max(self.v_ready[b.0 as usize]);
                let t = self.issue(Unit::Valu, ready);
                for k in 0..vlen {
                    let prod = self.vregs[a.0 as usize * vlen + k] * self.vregs[b.0 as usize * vlen + k];
                    self.vregs[acc.0 as usize * vlen + k] += prod;
                }
                self.v_ready[acc.0 as usize] = t + self.cfg.lat_vfma;
                self.retire(t + self.cfg.lat_vfma);
            }
            Instr::VFmaLane { acc, a, b, lane } => {
                let ready = self.v_ready[acc.0 as usize]
                    .max(self.v_ready[a.0 as usize])
                    .max(self.v_ready[b.0 as usize]);
                let t = self.issue(Unit::Valu, ready);
                let c = self.vregs[b.0 as usize * vlen + lane];
                for k in 0..vlen {
                    let prod = self.vregs[a.0 as usize * vlen + k] * c;
                    self.vregs[acc.0 as usize * vlen + k] += prod;
                }
                self.v_ready[acc.0 as usize] = t + self.cfg.lat_vfma;
                self.retire(t + self.cfg.lat_vfma);
            }
            Instr::VAdd { dst, a, b } => {
                let ready = self.v_ready[a.0 as usize].max(self.v_ready[b.0 as usize]);
                let t = self.issue(Unit::Valu, ready);
                for k in 0..vlen {
                    self.vregs[dst.0 as usize * vlen + k] =
                        self.vregs[a.0 as usize * vlen + k] + self.vregs[b.0 as usize * vlen + k];
                }
                self.v_ready[dst.0 as usize] = t + self.cfg.lat_vfma;
                self.retire(t + self.cfg.lat_vfma);
            }
            Instr::VMul { dst, a, b } => {
                let ready = self.v_ready[a.0 as usize].max(self.v_ready[b.0 as usize]);
                let t = self.issue(Unit::Valu, ready);
                for k in 0..vlen {
                    self.vregs[dst.0 as usize * vlen + k] =
                        self.vregs[a.0 as usize * vlen + k] * self.vregs[b.0 as usize * vlen + k];
                }
                self.v_ready[dst.0 as usize] = t + self.cfg.lat_vfma;
                self.retire(t + self.cfg.lat_vfma);
            }
            Instr::VZero { dst } => {
                let t = self.issue(Unit::Valu, 0);
                self.vregs[dst.0 as usize * vlen..(dst.0 as usize + 1) * vlen].fill(0.0);
                self.v_ready[dst.0 as usize] = t + 1;
                self.retire(t + 1);
            }
            Instr::MZero { m } => {
                let t = self.issue(Unit::Opu, self.m_accum_ready[m.0 as usize]);
                self.mregs[m.0 as usize * vlen * vlen..(m.0 as usize + 1) * vlen * vlen].fill(0.0);
                self.m_accum_ready[m.0 as usize] = t + 1;
                self.m_read_ready[m.0 as usize] = t + 1;
                self.retire(t + 1);
            }
            Instr::Fmopa { m, a, b } => {
                let ready = self.v_ready[a.0 as usize]
                    .max(self.v_ready[b.0 as usize])
                    .max(self.m_accum_ready[m.0 as usize]);
                let t = self.issue(Unit::Opu, ready);
                for i in 0..vlen {
                    let ai = self.vregs[a.0 as usize * vlen + i];
                    for j in 0..vlen {
                        self.mregs[m.0 as usize * vlen * vlen + (i * vlen + j)] +=
                            ai * self.vregs[b.0 as usize * vlen + j];
                    }
                }
                // accumulator forwarding for the next FMOPA; full latency
                // before the tile can be read out.
                self.m_accum_ready[m.0 as usize] = t + 1;
                let rr = t + self.cfg.lat_fmopa;
                if rr > self.m_read_ready[m.0 as usize] {
                    self.m_read_ready[m.0 as usize] = rr;
                }
                self.retire(rr);
            }
            Instr::MovVToMRow { m, row, src } => {
                let ready =
                    self.v_ready[src.0 as usize].max(self.m_accum_ready[m.0 as usize]);
                let t = self.issue(Unit::Valu, ready);
                for k in 0..vlen {
                    self.mregs[m.0 as usize * vlen * vlen + (row * vlen + k)] = self.vregs[src.0 as usize * vlen + k];
                }
                self.m_accum_ready[m.0 as usize] = t + 1;
                let rr = t + self.cfg.lat_mov;
                if rr > self.m_read_ready[m.0 as usize] {
                    self.m_read_ready[m.0 as usize] = rr;
                }
                self.retire(rr);
            }
            Instr::MovMRowToV { dst, m, row } => {
                let ready = self.m_read_ready[m.0 as usize];
                let t = self.issue(Unit::Valu, ready);
                for k in 0..vlen {
                    self.vregs[dst.0 as usize * vlen + k] = self.mregs[m.0 as usize * vlen * vlen + (row * vlen + k)];
                }
                self.v_ready[dst.0 as usize] = t + self.cfg.lat_mov;
                self.retire(t + self.cfg.lat_mov);
            }
            Instr::MovVToMCol { m, col, src } => {
                let ready =
                    self.v_ready[src.0 as usize].max(self.m_accum_ready[m.0 as usize]);
                let t = self.issue(Unit::Valu, ready);
                for i in 0..vlen {
                    self.mregs[m.0 as usize * vlen * vlen + (i * vlen + col)] = self.vregs[src.0 as usize * vlen + i];
                }
                self.m_accum_ready[m.0 as usize] = t + 1;
                let rr = t + self.cfg.lat_mov;
                if rr > self.m_read_ready[m.0 as usize] {
                    self.m_read_ready[m.0 as usize] = rr;
                }
                self.retire(rr);
            }
            Instr::MovMColToV { dst, m, col } => {
                let ready = self.m_read_ready[m.0 as usize];
                let t = self.issue(Unit::Valu, ready);
                for i in 0..vlen {
                    self.vregs[dst.0 as usize * vlen + i] = self.mregs[m.0 as usize * vlen * vlen + (i * vlen + col)];
                }
                self.v_ready[dst.0 as usize] = t + self.cfg.lat_mov;
                self.retire(t + self.cfg.lat_mov);
            }
            Instr::LdMRow { m, row, addr } => {
                let t = self.issue(Unit::Lsu, self.m_accum_ready[m.0 as usize]);
                let done = self.mem_access(t, addr, vlen, false);
                for k in 0..vlen {
                    self.mregs[m.0 as usize * vlen * vlen + (row * vlen + k)] = self.mem[addr + k];
                }
                self.m_accum_ready[m.0 as usize] = t + 1;
                if done > self.m_read_ready[m.0 as usize] {
                    self.m_read_ready[m.0 as usize] = done;
                }
                self.retire(done);
            }
            Instr::StMRow { m, row, addr } => {
                let ready = self.m_read_ready[m.0 as usize];
                let t = self.issue(Unit::Lsu, ready);
                let done = self.mem_access(t, addr, vlen, true);
                for k in 0..vlen {
                    self.mem[addr + k] = self.mregs[m.0 as usize * vlen * vlen + (row * vlen + k)];
                }
                self.retire(done);
            }
        }
    }
}

impl Sink for Machine {
    fn emit(&mut self, i: Instr) {
        self.exec(&i);
    }
}

/// The simulator is one of the kernel IR's two memory backends: grid
/// layouts and coefficient tables are planned against this trait, so the
/// same planning code also targets [`crate::kir::HostMachine`].
impl crate::kir::mem::Arena for Machine {
    fn vlen(&self) -> usize {
        self.cfg.vlen
    }

    fn alloc(&mut self, n: usize) -> usize {
        Machine::alloc(self, n)
    }

    fn write_mem(&mut self, addr: usize, data: &[f64]) {
        Machine::write_mem(self, addr, data)
    }

    fn read_mem(&self, addr: usize, n: usize) -> &[f64] {
        Machine::read_mem(self, addr, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::isa::MReg;

    fn m() -> Machine {
        Machine::new(SimConfig::default())
    }

    #[test]
    fn load_compute_store_roundtrip() {
        let mut mc = m();
        let a = mc.alloc(8);
        let b = mc.alloc(8);
        mc.write_mem(a, &[1., 2., 3., 4., 5., 6., 7., 8.]);
        mc.exec(&Instr::LdVec { dst: VReg(0), addr: a });
        mc.exec(&Instr::LdVec { dst: VReg(1), addr: a });
        mc.exec(&Instr::VZero { dst: VReg(2) });
        mc.exec(&Instr::VFma { acc: VReg(2), a: VReg(0), b: VReg(1) });
        mc.exec(&Instr::StVec { src: VReg(2), addr: b });
        let out = mc.read_mem(b, 8);
        assert_eq!(out, &[1., 4., 9., 16., 25., 36., 49., 64.]);
        let stats = mc.finish();
        assert_eq!(stats.instructions, 5);
        assert!(stats.cycles > 0);
    }

    #[test]
    fn fmopa_is_outer_product_accumulate() {
        let mut mc = m();
        let a = mc.alloc(8);
        let b = mc.alloc(8);
        mc.write_mem(a, &[1., 2., 3., 4., 5., 6., 7., 8.]);
        mc.write_mem(b, &[10., 20., 30., 40., 50., 60., 70., 80.]);
        mc.exec(&Instr::LdVec { dst: VReg(0), addr: a });
        mc.exec(&Instr::LdVec { dst: VReg(1), addr: b });
        mc.exec(&Instr::MZero { m: MReg(0) });
        mc.exec(&Instr::Fmopa { m: MReg(0), a: VReg(0), b: VReg(1) });
        mc.exec(&Instr::Fmopa { m: MReg(0), a: VReg(0), b: VReg(1) });
        // read row 2 back: m[2][j] = 2 * (3 * b[j])
        mc.exec(&Instr::MovMRowToV { dst: VReg(2), m: MReg(0), row: 2 });
        let c = mc.alloc(8);
        mc.exec(&Instr::StVec { src: VReg(2), addr: c });
        let row = mc.read_mem(c, 8);
        let expect: Vec<f64> = [10., 20., 30., 40., 50., 60., 70., 80.]
            .iter()
            .map(|x| 2.0 * 3.0 * x)
            .collect();
        assert_eq!(row, &expect[..]);
        assert_eq!(mc.finish().fmopa(), 2);
    }

    #[test]
    fn ext_assembles_shifted_vector() {
        let mut mc = m();
        let a = mc.alloc(16);
        mc.write_mem(a, &(0..16).map(|x| x as f64).collect::<Vec<_>>());
        mc.exec(&Instr::LdVec { dst: VReg(0), addr: a });
        mc.exec(&Instr::LdVec { dst: VReg(1), addr: a + 8 });
        mc.exec(&Instr::Ext { dst: VReg(2), lo: VReg(0), hi: VReg(1), shift: 3 });
        let out = mc.alloc(8);
        mc.exec(&Instr::StVec { src: VReg(2), addr: out });
        assert_eq!(mc.read_mem(out, 8), &[3., 4., 5., 6., 7., 8., 9., 10.]);
    }

    #[test]
    fn strided_gather_loads_column() {
        let mut mc = m();
        let a = mc.alloc(64);
        let vals: Vec<f64> = (0..64).map(|x| x as f64).collect();
        mc.write_mem(a, &vals);
        mc.exec(&Instr::LdVecStrided { dst: VReg(0), base: a + 2, stride: 8 });
        let out = mc.alloc(8);
        mc.exec(&Instr::StVec { src: VReg(0), addr: out });
        assert_eq!(mc.read_mem(out, 8), &[2., 10., 18., 26., 34., 42., 50., 58.]);
    }

    #[test]
    fn col_moves_transpose() {
        let mut mc = m();
        let a = mc.alloc(8);
        mc.write_mem(a, &[1., 2., 3., 4., 5., 6., 7., 8.]);
        mc.exec(&Instr::LdVec { dst: VReg(0), addr: a });
        // write the vector as column 5, read row 3 → lane 5 must be v[3]
        mc.exec(&Instr::MZero { m: MReg(1) });
        mc.exec(&Instr::MovVToMCol { m: MReg(1), col: 5, src: VReg(0) });
        mc.exec(&Instr::MovMRowToV { dst: VReg(1), m: MReg(1), row: 3 });
        let out = mc.alloc(8);
        mc.exec(&Instr::StVec { src: VReg(1), addr: out });
        let row = mc.read_mem(out, 8);
        assert_eq!(row[5], 4.0);
        assert_eq!(row[0], 0.0);
    }

    #[test]
    fn dual_issue_bounds_ipc() {
        // independent VZero instructions: IPC must not exceed issue width
        let mut mc = m();
        for k in 0..16u8 {
            mc.exec(&Instr::VZero { dst: VReg(k % 4) });
        }
        let s = mc.finish();
        assert!(s.ipc() <= mc.cfg.issue_width as f64 + 1e-9, "ipc={}", s.ipc());
    }

    #[test]
    fn fma_dependency_chain_pays_latency() {
        // 8 chained FMAs on one accumulator should take ~8 × lat_vfma.
        let mut mc = m();
        mc.exec(&Instr::VZero { dst: VReg(0) });
        mc.exec(&Instr::VZero { dst: VReg(1) });
        mc.exec(&Instr::VZero { dst: VReg(2) });
        let t0 = {
            let s = mc.finish();
            s.cycles
        };
        for _ in 0..8 {
            mc.exec(&Instr::VFma { acc: VReg(0), a: VReg(1), b: VReg(2) });
        }
        let s = mc.finish();
        assert!(s.cycles >= t0 + 8 * mc.cfg.lat_vfma - 4, "cycles={}", s.cycles);
    }

    #[test]
    fn fmopa_chain_is_pipelined() {
        // 32 FMOPAs to the same tile should take ~32 cycles (forwarding),
        // not 32 × lat_fmopa.
        let mut mc = m();
        mc.exec(&Instr::VZero { dst: VReg(0) });
        mc.exec(&Instr::VZero { dst: VReg(1) });
        mc.exec(&Instr::MZero { m: MReg(0) });
        for _ in 0..32 {
            mc.exec(&Instr::Fmopa { m: MReg(0), a: VReg(0), b: VReg(1) });
        }
        let s = mc.finish();
        assert!(s.cycles < 32 + 20, "cycles={}", s.cycles);
        assert!(s.cycles >= 32, "cycles={}", s.cycles);
    }

    #[test]
    fn cache_locality_speeds_up_second_pass() {
        let mut mc = m();
        let a = mc.alloc(8 * 1024); // 64 KB: fits L1
        for blk in 0..2 {
            for i in 0..1024usize {
                mc.exec(&Instr::LdVec { dst: VReg((i % 8) as u8), addr: a + i * 8 });
            }
            if blk == 0 {
                let cold = mc.finish();
                assert!(cold.cache.mem_accesses > 900);
            }
        }
        let warm = mc.finish();
        assert_eq!(warm.cache.mem_accesses, 0);
        assert_eq!(warm.cache.l1_hits, 1024);
    }

    #[test]
    fn alloc_guard_bands_do_not_overlap() {
        let mut mc = m();
        let a = mc.alloc(100);
        let b = mc.alloc(50);
        assert!(b >= a + 100 + 64);
        mc.write_mem(a + 99, &[7.0]);
        mc.write_mem(b, &[9.0]);
        assert_eq!(mc.read_mem(a + 99, 1), &[7.0]);
    }
}
