//! Machine configuration (§5.1 parameters, all overridable).

/// Cache hierarchy parameters (Kunpeng 920-like, §5.1).
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    /// L1 data cache capacity in bytes (64 KB).
    pub l1_bytes: usize,
    /// L1 associativity.
    pub l1_assoc: usize,
    /// L2 (private) capacity in bytes (512 KB).
    pub l2_bytes: usize,
    /// L2 associativity.
    pub l2_assoc: usize,
    /// Cache line size in bytes.
    pub line_bytes: usize,
    /// Load-to-use latency on an L1 hit (cycles).
    pub lat_l1: u64,
    /// Load-to-use latency on an L1 miss / L2 hit.
    pub lat_l2: u64,
    /// Load-to-use latency on an L2 miss (memory).
    pub lat_mem: u64,
    /// DRAM bandwidth model: minimum cycles between two line transfers
    /// from memory (12 ⇒ ~5.3 B/cycle sustained, a realistic single-core STREAM
    /// ratio; this is what makes out-of-cache problem sizes
    /// bandwidth-bound rather than latency-bound).
    pub mem_line_interval: u64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self {
            l1_bytes: 64 * 1024,
            l1_assoc: 4,
            l2_bytes: 512 * 1024,
            l2_assoc: 8,
            line_bytes: 64,
            lat_l1: 4,
            lat_l2: 14,
            lat_mem: 100,
            mem_line_interval: 12,
        }
    }
}

/// Full machine configuration.
///
/// Defaults mirror the paper's simulator setup (§5.1): 512-bit vectors
/// (8 × f64), 8×8 matrix registers, 32 vector + 8 matrix registers, one
/// outer-product unit, plus a dual-issue in-order front end and two vector
/// ALU pipes (typical of the Kunpeng-920-class core the memory hierarchy
/// is modeled after).
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    /// Vector length in f64 lanes (512-bit ⇒ 8).
    pub vlen: usize,
    /// Number of architectural vector registers.
    pub n_vregs: usize,
    /// Number of architectural matrix registers (`vlen × vlen` each).
    pub n_mregs: usize,
    /// Instructions issued per cycle (in order).
    pub issue_width: usize,
    /// Number of outer-product units (§5.1 sets 1).
    pub opu_units: usize,
    /// Number of vector ALU pipes (FMA/EXT/moves).
    pub valu_units: usize,
    /// Number of load/store pipes.
    pub lsu_units: usize,
    /// FMOPA issue-to-result latency (cycles). Back-to-back FMOPA to the
    /// same accumulator are pipelined (accumulator forwarding), so this
    /// latency is only paid by *reads* of the matrix register.
    pub lat_fmopa: u64,
    /// Vector FMA latency.
    pub lat_vfma: u64,
    /// Vector EXT / register re-organization latency.
    pub lat_ext: u64,
    /// Matrix ↔ vector move latency.
    pub lat_mov: u64,
    /// Max outstanding cache misses (MSHRs).
    pub mshrs: usize,
    /// Extra cycles for a vector memory access whose 64-byte footprint
    /// crosses a cache-line boundary (the unaligned-access penalty that
    /// makes the data-alignment conflict of §4.3 visible).
    pub split_line_penalty: u64,
    /// Cache hierarchy.
    pub cache: CacheConfig,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            vlen: 8,
            n_vregs: 32,
            n_mregs: 8,
            issue_width: 2,
            opu_units: 1,
            valu_units: 2,
            lsu_units: 2,
            lat_fmopa: 4,
            lat_vfma: 4,
            lat_ext: 2,
            lat_mov: 2,
            mshrs: 8,
            split_line_penalty: 1,
            cache: CacheConfig::default(),
        }
    }
}

impl SimConfig {
    /// Bytes per vector register.
    pub fn vector_bytes(&self) -> usize {
        self.vlen * 8
    }

    /// A config with double the matrix registers (ablation §DESIGN).
    pub fn with_mregs(mut self, n: usize) -> Self {
        self.n_mregs = n;
        self
    }

    /// Override the vector length (must divide the problem sizes used).
    pub fn with_vlen(mut self, vlen: usize) -> Self {
        self.vlen = vlen;
        self
    }

    /// A stable 16-hex-digit fingerprint over **every** machine parameter
    /// (FNV-1a over a canonical field dump). Two configs share a
    /// fingerprint iff they describe the same simulated machine; the
    /// tuning database uses it as part of its key, so tuned plans are
    /// never silently reused across machine models.
    pub fn fingerprint(&self) -> String {
        let c = &self.cache;
        let canon = format!(
            "vlen={} vregs={} mregs={} issue={} opu={} valu={} lsu={} \
             lat_fmopa={} lat_vfma={} lat_ext={} lat_mov={} mshrs={} split={} \
             l1={}x{} l2={}x{} line={} lat={}:{}:{} mli={}",
            self.vlen,
            self.n_vregs,
            self.n_mregs,
            self.issue_width,
            self.opu_units,
            self.valu_units,
            self.lsu_units,
            self.lat_fmopa,
            self.lat_vfma,
            self.lat_ext,
            self.lat_mov,
            self.mshrs,
            self.split_line_penalty,
            c.l1_bytes,
            c.l1_assoc,
            c.l2_bytes,
            c.l2_assoc,
            c.line_bytes,
            c.lat_l1,
            c.lat_l2,
            c.lat_mem,
            c.mem_line_interval,
        );
        let mut h: u64 = 0xcbf29ce484222325;
        for b in canon.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        format!("{h:016x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_section_5_1() {
        let c = SimConfig::default();
        assert_eq!(c.vlen, 8); // 512-bit / f64
        assert_eq!(c.n_vregs, 32);
        assert_eq!(c.n_mregs, 8);
        assert_eq!(c.opu_units, 1);
        assert_eq!(c.cache.l1_bytes, 64 * 1024);
        assert_eq!(c.cache.l2_bytes, 512 * 1024);
        assert_eq!(c.vector_bytes(), 64);
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let a = SimConfig::default().fingerprint();
        assert_eq!(a.len(), 16);
        assert_eq!(a, SimConfig::default().fingerprint());
        assert_ne!(a, SimConfig::default().with_mregs(16).fingerprint());
        assert_ne!(a, SimConfig::default().with_vlen(4).fingerprint());
        let mut c = SimConfig::default();
        c.cache.l2_bytes *= 2;
        assert_ne!(a, c.fingerprint());
    }
}
