//! The simulator's instruction set.
//!
//! Mirrors the instruction classes the paper relies on (§3.1 observations):
//! vector-granularity matrix-register assembly (no intra-/inter-matrix
//! re-organization), a rich set of vector re-organization instructions
//! (`Ext`), and the outer-product accumulate (`Fmopa`) with the matrix
//! register as both input and output.
//!
//! Addresses are **element indices** (f64 slots) into the machine's flat
//! memory; the cache model converts to bytes internally.

/// Register ids are owned by the backend-agnostic kernel IR (the
/// generators emit KIR; this ISA is the sim lowering target) and
/// re-exported here so simulator code keeps its familiar names.
pub use crate::kir::ir::{MReg, VReg};

/// One machine instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum Instr {
    // ---- memory, vector granularity ----
    /// `dst <- mem[addr .. addr+vlen]` (contiguous).
    LdVec { dst: VReg, addr: usize },
    /// `mem[addr .. addr+vlen] <- src`.
    StVec { src: VReg, addr: usize },
    /// Gather load: `dst[k] <- mem[base + k*stride]`. Models the
    /// "memory inefficient" strided access of §4.1; issues one cache
    /// access per element.
    LdVecStrided { dst: VReg, base: usize, stride: usize },
    /// Broadcast load: `dst[k] <- mem[addr]` for all lanes.
    LdSplat { dst: VReg, addr: usize },
    /// Store a single lane: `mem[addr] <- src[lane]` (scalar stores for
    /// the scalar baseline and edge handling).
    StLane { src: VReg, lane: usize, addr: usize },

    // ---- vector register re-organization (§3.1: "cheap and flexible") ----
    /// `dst <- (lo ++ hi)[shift .. shift+vlen]` — the inter-register
    /// assembling of §4.3 (NEON/SVE `EXT`).
    Ext { dst: VReg, lo: VReg, hi: VReg, shift: usize },
    /// Broadcast one lane: `dst[k] <- src[lane]` for all `k`.
    Dup { dst: VReg, src: VReg, lane: usize },

    // ---- vector arithmetic ----
    /// `acc[k] += a[k] * b[k]` (predicated FMA).
    VFma { acc: VReg, a: VReg, b: VReg },
    /// `acc[k] += a[k] * b[lane]` (indexed FMA — coefficient broadcast).
    VFmaLane { acc: VReg, a: VReg, b: VReg, lane: usize },
    /// `dst[k] = a[k] + b[k]`.
    VAdd { dst: VReg, a: VReg, b: VReg },
    /// `dst[k] = a[k] * b[k]`.
    VMul { dst: VReg, a: VReg, b: VReg },
    /// `dst[k] = 0`.
    VZero { dst: VReg },

    // ---- matrix (tile) operations ----
    /// Zero the whole tile.
    MZero { m: MReg },
    /// Outer product accumulate: `m[i][j] += a[i] * b[j]` (SME `FMOPA`).
    Fmopa { m: MReg, a: VReg, b: VReg },
    /// `m[row][*] <- src` (vector → tile row move).
    MovVToMRow { m: MReg, row: usize, src: VReg },
    /// `dst <- m[row][*]` (tile row → vector move).
    MovMRowToV { dst: VReg, m: MReg, row: usize },
    /// `m[*][col] <- src` (vector → tile column move; SME supports both
    /// orientations on ZA slices).
    MovVToMCol { m: MReg, col: usize, src: VReg },
    /// `dst <- m[*][col]` (tile column → vector move — the transpose
    /// building block of §4.1).
    MovMColToV { dst: VReg, m: MReg, col: usize },
    /// `m[row][*] <- mem[addr .. addr+vlen]` (vector-granularity tile
    /// fill straight from memory).
    LdMRow { m: MReg, row: usize, addr: usize },
    /// `mem[addr .. addr+vlen] <- m[row][*]`.
    StMRow { m: MReg, row: usize, addr: usize },
}

/// Number of distinct opcodes (for fixed-size counters).
pub const N_OPCODES: usize = 20;

/// Mnemonic per opcode index (same order as [`Instr::opcode`]).
pub const OPCODE_MNEMONICS: [&str; N_OPCODES] = [
    "ld1d",
    "st1d",
    "ld1d.gather",
    "ld1rd",
    "st1d.lane",
    "ext",
    "dup",
    "fmla",
    "fmla.idx",
    "fadd",
    "fmul",
    "vzero",
    "zero.za",
    "fmopa",
    "mova.h.in",
    "mova.h.out",
    "mova.v.in",
    "mova.v.out",
    "ld1d.za",
    "st1d.za",
];

impl Instr {
    /// Dense opcode index (see [`OPCODE_MNEMONICS`]).
    pub fn opcode(&self) -> u8 {
        match self {
            Instr::LdVec { .. } => 0,
            Instr::StVec { .. } => 1,
            Instr::LdVecStrided { .. } => 2,
            Instr::LdSplat { .. } => 3,
            Instr::StLane { .. } => 4,
            Instr::Ext { .. } => 5,
            Instr::Dup { .. } => 6,
            Instr::VFma { .. } => 7,
            Instr::VFmaLane { .. } => 8,
            Instr::VAdd { .. } => 9,
            Instr::VMul { .. } => 10,
            Instr::VZero { .. } => 11,
            Instr::MZero { .. } => 12,
            Instr::Fmopa { .. } => 13,
            Instr::MovVToMRow { .. } => 14,
            Instr::MovMRowToV { .. } => 15,
            Instr::MovVToMCol { .. } => 16,
            Instr::MovMColToV { .. } => 17,
            Instr::LdMRow { .. } => 18,
            Instr::StMRow { .. } => 19,
        }
    }

    /// Floating-point operations this instruction performs (mul + add).
    pub fn flops(&self, vlen: usize) -> u64 {
        match self {
            Instr::VFma { .. } | Instr::VFmaLane { .. } => 2 * vlen as u64,
            Instr::VAdd { .. } | Instr::VMul { .. } => vlen as u64,
            Instr::Fmopa { .. } => 2 * (vlen * vlen) as u64,
            _ => 0,
        }
    }

    /// Short mnemonic for traces and instruction-mix stats.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instr::LdVec { .. } => "ld1d",
            Instr::StVec { .. } => "st1d",
            Instr::LdVecStrided { .. } => "ld1d.gather",
            Instr::LdSplat { .. } => "ld1rd",
            Instr::StLane { .. } => "st1d.lane",
            Instr::Ext { .. } => "ext",
            Instr::Dup { .. } => "dup",
            Instr::VFma { .. } => "fmla",
            Instr::VFmaLane { .. } => "fmla.idx",
            Instr::VAdd { .. } => "fadd",
            Instr::VMul { .. } => "fmul",
            Instr::VZero { .. } => "vzero",
            Instr::MZero { .. } => "zero.za",
            Instr::Fmopa { .. } => "fmopa",
            Instr::MovVToMRow { .. } => "mova.h.in",
            Instr::MovMRowToV { .. } => "mova.h.out",
            Instr::MovVToMCol { .. } => "mova.v.in",
            Instr::MovMColToV { .. } => "mova.v.out",
            Instr::LdMRow { .. } => "ld1d.za",
            Instr::StMRow { .. } => "st1d.za",
        }
    }

    /// True for instructions that touch memory.
    pub fn is_mem(&self) -> bool {
        matches!(
            self,
            Instr::LdVec { .. }
                | Instr::StVec { .. }
                | Instr::LdVecStrided { .. }
                | Instr::LdSplat { .. }
                | Instr::StLane { .. }
                | Instr::LdMRow { .. }
                | Instr::StMRow { .. }
        )
    }
}

/// Consumer of generated instructions.
///
/// Code generators emit into a `Sink` so programs can be executed
/// on-the-fly by [`crate::sim::Machine`] (no multi-megabyte program
/// buffers) or captured into a [`Program`] for inspection and tests.
pub trait Sink {
    /// Consume one instruction.
    fn emit(&mut self, i: Instr);
}

/// A captured instruction stream.
#[derive(Debug, Default, Clone)]
pub struct Program(pub Vec<Instr>);

impl Sink for Program {
    fn emit(&mut self, i: Instr) {
        self.0.push(i);
    }
}

impl Program {
    /// Count instructions matching a predicate.
    pub fn count(&self, pred: impl Fn(&Instr) -> bool) -> usize {
        self.0.iter().filter(|i| pred(i)).count()
    }

    /// Number of `Fmopa` instructions (what Table 1/2 count).
    pub fn fmopa_count(&self) -> usize {
        self.count(|i| matches!(i, Instr::Fmopa { .. }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_accounting() {
        let v = VReg(0);
        let m = MReg(0);
        assert_eq!(Instr::VFma { acc: v, a: v, b: v }.flops(8), 16);
        assert_eq!(Instr::Fmopa { m, a: v, b: v }.flops(8), 128);
        assert_eq!(Instr::LdVec { dst: v, addr: 0 }.flops(8), 0);
    }

    #[test]
    fn mem_classification() {
        let v = VReg(1);
        assert!(Instr::LdVec { dst: v, addr: 4 }.is_mem());
        assert!(Instr::StMRow { m: MReg(0), row: 1, addr: 0 }.is_mem());
        assert!(!Instr::Ext { dst: v, lo: v, hi: v, shift: 3 }.is_mem());
    }

    #[test]
    fn program_counts() {
        let mut p = Program::default();
        p.emit(Instr::MZero { m: MReg(0) });
        p.emit(Instr::Fmopa { m: MReg(0), a: VReg(0), b: VReg(1) });
        p.emit(Instr::Fmopa { m: MReg(0), a: VReg(0), b: VReg(2) });
        assert_eq!(p.fmopa_count(), 2);
        assert_eq!(p.0.len(), 3);
    }
}
