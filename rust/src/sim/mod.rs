//! The evaluation substrate: a configurable, SME-like functional + timing
//! simulator.
//!
//! The paper evaluates on a proprietary cycle-accurate ARM simulator
//! (§5.1: 512-bit vectors ⇒ 8 f64 lanes, 8×8 matrix registers, 32 vector +
//! 8 matrix registers, one outer-product unit, 64 KB L1D, 512 KB L2). This
//! module is our open substitute. It is *functional* — every instruction
//! computes real values, so generated programs are verified element-wise
//! against the scalar reference — and *cycle-approximate*: an in-order,
//! multi-issue scoreboard with per-unit latency/throughput, a two-level
//! write-back LRU cache model, and an MSHR cap on outstanding misses.
//!
//! - [`isa`] — the instruction set (vector loads/stores, register
//!   re-organization, vector FMA, outer product `FMOPA`, matrix ↔ vector
//!   moves).
//! - [`config`] — machine parameters (§5.1 defaults, fully configurable).
//! - [`cache`] — L1/L2/memory hierarchy with traffic accounting.
//! - [`machine`] — functional execution + timing scoreboard.
//! - [`stats`] — cycle/instruction/traffic counters and derived metrics.

pub mod cache;
pub mod config;
pub mod isa;
pub mod machine;
pub mod stats;
pub mod trace;

pub use config::SimConfig;
pub use isa::{Instr, MReg, Sink, VReg};
pub use machine::Machine;
pub use stats::RunStats;
