//! Two-level write-back, write-allocate LRU cache model with traffic
//! accounting.
//!
//! The paper's performance story is largely about memory behaviour
//! (in-cache vs out-of-cache problem sizes, §5.2), so the hierarchy is
//! modeled explicitly: 64 KB L1D and 512 KB private L2 by default, 64-byte
//! lines, inclusive, LRU per set.

use super::config::CacheConfig;

/// Which level served an access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HitLevel {
    /// Served by L1.
    L1,
    /// L1 miss, L2 hit.
    L2,
    /// Missed both levels (memory).
    Mem,
}

/// One set-associative level.
struct Level {
    sets: usize,
    assoc: usize,
    /// `tags[set * assoc + way]` = line tag, `u64::MAX` = invalid.
    tags: Vec<u64>,
    /// LRU stamps, larger = more recent.
    stamp: Vec<u64>,
    /// Dirty bits.
    dirty: Vec<bool>,
    clock: u64,
}

impl Level {
    fn new(bytes: usize, assoc: usize, line: usize) -> Self {
        let lines = bytes / line;
        let sets = (lines / assoc).max(1);
        Self {
            sets,
            assoc,
            tags: vec![u64::MAX; sets * assoc],
            stamp: vec![0; sets * assoc],
            dirty: vec![false; sets * assoc],
            clock: 0,
        }
    }

    /// Look up `line_addr`; on hit, refresh LRU and (if `write`) mark
    /// dirty. Returns true on hit.
    fn access(&mut self, line_addr: u64, write: bool) -> bool {
        let set = (line_addr as usize) % self.sets;
        self.clock += 1;
        for way in 0..self.assoc {
            let i = set * self.assoc + way;
            if self.tags[i] == line_addr {
                self.stamp[i] = self.clock;
                if write {
                    self.dirty[i] = true;
                }
                return true;
            }
        }
        false
    }

    /// Insert `line_addr`, evicting LRU. Returns the evicted line if it
    /// was valid and dirty (must be written back).
    fn fill(&mut self, line_addr: u64, write: bool) -> Option<u64> {
        let set = (line_addr as usize) % self.sets;
        self.clock += 1;
        let mut victim = set * self.assoc;
        for way in 1..self.assoc {
            let i = set * self.assoc + way;
            if self.tags[i] == u64::MAX {
                victim = i;
                break;
            }
            if self.stamp[i] < self.stamp[victim] {
                victim = i;
            }
        }
        let evicted = if self.tags[victim] != u64::MAX && self.dirty[victim] {
            Some(self.tags[victim])
        } else {
            None
        };
        self.tags[victim] = line_addr;
        self.stamp[victim] = self.clock;
        self.dirty[victim] = write;
        evicted
    }
}

/// Per-level hit counters and inter-level traffic.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct CacheStats {
    /// Accesses served by L1.
    pub l1_hits: u64,
    /// Accesses served by L2.
    pub l2_hits: u64,
    /// Accesses served by memory.
    pub mem_accesses: u64,
    /// Bytes moved L2 → L1 (fills).
    pub l1_fill_bytes: u64,
    /// Bytes moved memory → L2 (fills).
    pub l2_fill_bytes: u64,
    /// Bytes written back L1 → L2 / L2 → memory.
    pub writeback_bytes: u64,
}

/// The two-level hierarchy with a simple stream prefetcher.
pub struct CacheSim {
    cfg: CacheConfig,
    l1: Level,
    l2: Level,
    /// Ring of recently-missed line addresses (stream detector).
    recent_miss: [u64; 32],
    recent_head: usize,
    /// Counters.
    pub stats: CacheStats,
}

impl CacheSim {
    /// Build from a config.
    pub fn new(cfg: &CacheConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            l1: Level::new(cfg.l1_bytes, cfg.l1_assoc, cfg.line_bytes),
            l2: Level::new(cfg.l2_bytes, cfg.l2_assoc, cfg.line_bytes),
            recent_miss: [u64::MAX; 32],
            recent_head: 0,
            stats: CacheStats::default(),
        }
    }

    /// Stream detector: a miss to line `l` whose predecessor lines missed
    /// recently would have been prefetched by the L1/L2 stream prefetcher
    /// of the modeled core — its latency is mostly hidden.
    fn prefetched(&mut self, line: u64) -> bool {
        let hit = self
            .recent_miss
            .iter()
            .any(|&m| m != u64::MAX && (m == line.wrapping_sub(1) || m == line.wrapping_sub(2)));
        self.recent_miss[self.recent_head] = line;
        self.recent_head = (self.recent_head + 1) % self.recent_miss.len();
        hit
    }

    /// Access one cache line containing byte address `byte_addr`. Returns
    /// the level that served it and the load-to-use latency.
    pub fn access_line(&mut self, byte_addr: u64, write: bool) -> (HitLevel, u64) {
        let line = byte_addr / self.cfg.line_bytes as u64;
        if self.l1.access(line, write) {
            self.stats.l1_hits += 1;
            return (HitLevel::L1, self.cfg.lat_l1);
        }
        let streamed = self.prefetched(line);
        // L1 miss: fill from L2 (or memory).
        let level = if self.l2.access(line, false) {
            self.stats.l2_hits += 1;
            HitLevel::L2
        } else {
            self.stats.mem_accesses += 1;
            self.stats.l2_fill_bytes += self.cfg.line_bytes as u64;
            if let Some(_evicted) = self.l2.fill(line, false) {
                self.stats.writeback_bytes += self.cfg.line_bytes as u64;
            }
            HitLevel::Mem
        };
        self.stats.l1_fill_bytes += self.cfg.line_bytes as u64;
        if let Some(evicted) = self.l1.fill(line, write) {
            // dirty L1 eviction: write back into L2
            self.stats.writeback_bytes += self.cfg.line_bytes as u64;
            if !self.l2.access(evicted, true) {
                if self.l2.fill(evicted, true).is_some() {
                    self.stats.writeback_bytes += self.cfg.line_bytes as u64;
                }
                self.stats.l2_fill_bytes += self.cfg.line_bytes as u64;
            }
        }
        let lat = match (level, streamed) {
            // prefetched stream: data was already on its way; a small
            // residual latency remains (timeliness is never perfect)
            (HitLevel::Mem, true) => self.cfg.lat_l2,
            (HitLevel::L2, true) => self.cfg.lat_l1 + 2,
            (HitLevel::L2, false) => self.cfg.lat_l2,
            _ => self.cfg.lat_mem,
        };
        (level, lat)
    }

    /// Access a byte range `[byte_addr, byte_addr + len)`; returns the
    /// worst-case latency over the touched lines, how many lines were
    /// touched (to model split-line penalties), and how many went all the
    /// way to memory (for the DRAM bandwidth model).
    pub fn access_range(&mut self, byte_addr: u64, len: u64, write: bool) -> (u64, u64, u64) {
        let line = self.cfg.line_bytes as u64;
        let first = byte_addr / line;
        let last = (byte_addr + len - 1) / line;
        let mut worst = 0;
        let mut mem_lines = 0;
        for l in first..=last {
            let (lvl, lat) = self.access_line(l * line, write);
            worst = worst.max(lat);
            if lvl == HitLevel::Mem {
                mem_lines += 1;
            }
        }
        (worst, last - first + 1, mem_lines)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> CacheConfig {
        CacheConfig {
            l1_bytes: 256, // 4 lines
            l1_assoc: 2,
            l2_bytes: 1024, // 16 lines
            l2_assoc: 2,
            line_bytes: 64,
            lat_l1: 4,
            lat_l2: 14,
            lat_mem: 100,
            mem_line_interval: 12,
        }
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = CacheSim::new(&tiny_cfg());
        let (lvl, lat) = c.access_line(0, false);
        assert_eq!(lvl, HitLevel::Mem);
        assert_eq!(lat, 100);
        let (lvl, lat) = c.access_line(8, false); // same line
        assert_eq!(lvl, HitLevel::L1);
        assert_eq!(lat, 4);
    }

    #[test]
    fn l1_eviction_falls_back_to_l2() {
        let mut c = CacheSim::new(&tiny_cfg());
        // L1: 2 sets × 2 ways. Lines 0, 2, 4 map to set 0; fill 3 of them.
        c.access_line(0, false);
        c.access_line(2 * 64, false);
        c.access_line(4 * 64, false); // evicts line 0 from L1
        let (lvl, _) = c.access_line(0, false);
        assert_eq!(lvl, HitLevel::L2, "should still be resident in L2");
    }

    #[test]
    fn lru_order_respected() {
        let mut c = CacheSim::new(&tiny_cfg());
        c.access_line(0, false); // set 0
        c.access_line(2 * 64, false); // set 0 — L1 set full
        c.access_line(0, false); // refresh line 0
        c.access_line(4 * 64, false); // evicts line 2 (LRU), not line 0
        assert_eq!(c.access_line(0, false).0, HitLevel::L1);
        assert_eq!(c.access_line(2 * 64, false).0, HitLevel::L2);
    }

    #[test]
    fn writeback_traffic_counted() {
        let mut c = CacheSim::new(&tiny_cfg());
        c.access_line(0, true); // dirty line 0 in L1
        c.access_line(2 * 64, false);
        c.access_line(4 * 64, false); // evicts dirty line 0 → writeback
        assert!(c.stats.writeback_bytes >= 64);
    }

    #[test]
    fn split_range_touches_two_lines() {
        let mut c = CacheSim::new(&tiny_cfg());
        let (_, lines, _) = c.access_range(32, 64, false); // crosses 0→1
        assert_eq!(lines, 2);
        let (_, lines, _) = c.access_range(64, 64, false); // aligned
        assert_eq!(lines, 1);
    }

    #[test]
    fn working_set_fits_l1_all_hits_after_warmup() {
        let cfg = tiny_cfg();
        let mut c = CacheSim::new(&cfg);
        // 4 lines working set, L1 holds 4 lines across 2 sets × 2 ways:
        // lines 0..4 map sets 0,1,0,1 — exactly fits.
        for pass in 0..3 {
            for l in 0..4u64 {
                let (lvl, _) = c.access_line(l * 64, false);
                if pass > 0 {
                    assert_eq!(lvl, HitLevel::L1, "pass {pass} line {l}");
                }
            }
        }
    }
}
