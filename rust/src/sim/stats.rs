//! Run statistics: cycles, instruction mix, FLOPs, memory behaviour.

use super::cache::CacheStats;
use std::collections::BTreeMap;
use std::fmt;

/// Counters produced by one simulated program run.
#[derive(Debug, Default, Clone)]
pub struct RunStats {
    /// Total simulated cycles (completion time of the last instruction).
    pub cycles: u64,
    /// Instructions executed.
    pub instructions: u64,
    /// Floating-point operations performed (multiplies + adds).
    pub flops: u64,
    /// Instruction counts by mnemonic.
    pub mix: BTreeMap<&'static str, u64>,
    /// Cycles lost waiting for a free MSHR (memory-parallelism limit).
    pub mshr_stall_cycles: u64,
    /// Cache hierarchy counters.
    pub cache: CacheStats,
}

impl RunStats {
    /// FLOPs per cycle — the utilization metric used in EXPERIMENTS.md.
    pub fn flops_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.flops as f64 / self.cycles as f64
        }
    }

    /// Instructions per cycle.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.instructions as f64 / self.cycles as f64
        }
    }

    /// Count for one mnemonic.
    pub fn count(&self, mnemonic: &str) -> u64 {
        self.mix.get(mnemonic).copied().unwrap_or(0)
    }

    /// Number of outer products executed.
    pub fn fmopa(&self) -> u64 {
        self.count("fmopa")
    }

    /// Total bytes moved from memory into the hierarchy.
    pub fn mem_bytes(&self) -> u64 {
        self.cache.l2_fill_bytes + self.cache.writeback_bytes
    }

    /// Merge another run's counters into this one (used by multi-pass
    /// harness runs).
    pub fn merge(&mut self, other: &RunStats) {
        self.cycles += other.cycles;
        self.instructions += other.instructions;
        self.flops += other.flops;
        self.mshr_stall_cycles += other.mshr_stall_cycles;
        for (k, v) in &other.mix {
            *self.mix.entry(k).or_insert(0) += v;
        }
        self.cache.l1_hits += other.cache.l1_hits;
        self.cache.l2_hits += other.cache.l2_hits;
        self.cache.mem_accesses += other.cache.mem_accesses;
        self.cache.l1_fill_bytes += other.cache.l1_fill_bytes;
        self.cache.l2_fill_bytes += other.cache.l2_fill_bytes;
        self.cache.writeback_bytes += other.cache.writeback_bytes;
    }
}

impl fmt::Display for RunStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "cycles={} instrs={} ipc={:.2} flops={} flops/cyc={:.2}",
            self.cycles,
            self.instructions,
            self.ipc(),
            self.flops,
            self.flops_per_cycle()
        )?;
        writeln!(
            f,
            "cache: L1 {} / L2 {} / mem {}  traffic: L1-fill {} B, L2-fill {} B, WB {} B",
            self.cache.l1_hits,
            self.cache.l2_hits,
            self.cache.mem_accesses,
            self.cache.l1_fill_bytes,
            self.cache.l2_fill_bytes,
            self.cache.writeback_bytes
        )?;
        write!(f, "mix:")?;
        for (k, v) in &self.mix {
            write!(f, " {k}={v}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_metrics() {
        let mut s = RunStats { cycles: 100, instructions: 150, flops: 400, ..Default::default() };
        s.mix.insert("fmopa", 3);
        assert_eq!(s.ipc(), 1.5);
        assert_eq!(s.flops_per_cycle(), 4.0);
        assert_eq!(s.fmopa(), 3);
        assert_eq!(s.count("missing"), 0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = RunStats { cycles: 10, instructions: 5, flops: 20, ..Default::default() };
        a.mix.insert("fmla", 2);
        let mut b = RunStats { cycles: 7, instructions: 3, flops: 12, ..Default::default() };
        b.mix.insert("fmla", 1);
        b.mix.insert("fmopa", 4);
        a.merge(&b);
        assert_eq!(a.cycles, 17);
        assert_eq!(a.count("fmla"), 3);
        assert_eq!(a.count("fmopa"), 4);
    }

    #[test]
    fn zero_cycles_safe() {
        let s = RunStats::default();
        assert_eq!(s.ipc(), 0.0);
        assert_eq!(s.flops_per_cycle(), 0.0);
    }
}
