//! `cargo bench --bench table3_speedups` — regenerates Table 3: speedups
//! over auto-vectorization for the full 2D/3D stencil × size matrix, with
//! the best option label per cell, plus the extra ablations.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::bench_harness::{ablation, table3};
use stencil_matrix::sim::SimConfig;
use stencil_matrix::util::bench::{fmt_secs, time_it};

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig::default();
    let (best, _) = time_it(1, || {
        for r in table3::run_all(&cfg).expect("table3") {
            r.emit().expect("emit");
        }
        for r in ablation::run_all(&cfg).expect("ablation") {
            r.emit().expect("emit");
        }
    });
    eprintln!("table3 + ablations wall-clock: {}", fmt_secs(best));
    Ok(())
}
