//! `cargo bench --bench fig3_cls_options` — regenerates Figure 3:
//! star-stencil coefficient-line options (parallel/orthogonal/hybrid)
//! across orders, panels (a)–(d). Reports simulated cycles/point
//! (deterministic) plus host wall-clock for the simulation itself.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::bench_harness::fig3;
use stencil_matrix::sim::SimConfig;
use stencil_matrix::util::bench::{fmt_secs, time_it};

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig::default();
    let (best, _) = time_it(1, || {
        for r in fig3::run_all(&cfg).expect("fig3") {
            r.emit().expect("emit");
        }
    });
    eprintln!("fig3 harness wall-clock: {}", fmt_secs(best));
    Ok(())
}
