//! `cargo bench --bench fig5_methods_r1` — regenerates Figure 5:
//! autovec / DLT / TV / ours for r = 1 stencils across four sizes each.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::bench_harness::fig5;
use stencil_matrix::sim::SimConfig;
use stencil_matrix::util::bench::{fmt_secs, time_it};

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig::default();
    let (best, _) = time_it(1, || {
        for r in fig5::run_all(&cfg).expect("fig5") {
            r.emit().expect("emit");
        }
    });
    eprintln!("fig5 harness wall-clock: {}", fmt_secs(best));
    Ok(())
}
