//! `cargo bench --bench simulator_hotpath` — the §Perf L3 profile: how
//! fast the simulator itself executes instructions (host side), plus the
//! per-method simulated-instruction throughput on a large workload. This
//! is the bench the EXPERIMENTS.md §Perf before/after numbers come from.

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::codegen::{run_method, Method, OuterParams};
use stencil_matrix::stencil::StencilSpec;
use stencil_matrix::sim::{Instr, Machine, SimConfig, VReg};
use stencil_matrix::util::bench::{fmt_secs, time_it};

fn raw_exec_throughput() {
    // microbenchmark: a tight ld/fma/st loop through the full machine
    // (functional + timing + cache), ~1M instructions per pass
    let cfg = SimConfig::default();
    let mut m = Machine::new(cfg);
    let a = m.alloc(8 * 1024);
    let total = 1_000_000usize;
    let (best, _) = time_it(3, || {
        for i in 0..total / 3 {
            let addr = a + (i * 8) % (8 * 1024 - 8);
            m.exec(&Instr::LdVec { dst: VReg((i % 8) as u8), addr });
            m.exec(&Instr::VFma {
                acc: VReg(8 + (i % 8) as u8),
                a: VReg((i % 8) as u8),
                b: VReg(16),
            });
            m.exec(&Instr::StVec { src: VReg(8 + (i % 8) as u8), addr });
        }
        m.finish();
    });
    println!(
        "raw machine exec: {:.1} M simulated instrs/s ({} per pass)",
        total as f64 / best / 1e6,
        fmt_secs(best)
    );
}

fn end_to_end(label: &str, spec: StencilSpec, n: usize, method: Method) {
    let cfg = SimConfig::default();
    let mut instrs = 0u64;
    let (best, _) = time_it(2, || {
        let res = run_method(&cfg, spec, n, method, true).expect("run");
        assert!(res.verified());
        instrs = res.stats.instructions;
    });
    println!(
        "{label:24} {spec} N={n}: {} ({:.1} M simulated instrs/s incl. generation+verify)",
        fmt_secs(best),
        // two generation passes (warm + measured) per timed run
        2.0 * instrs as f64 / best / 1e6
    );
}

fn main() {
    raw_exec_throughput();
    let box2d = StencilSpec::box2d(1);
    end_to_end("outer (paper best)", box2d, 512, Method::Outer(OuterParams::paper_best(box2d)));
    end_to_end("autovec", box2d, 512, Method::AutoVec);
    let box3d = StencilSpec::box3d(1);
    end_to_end("outer 3D", box3d, 64, Method::Outer(OuterParams::paper_best(box3d)));
    end_to_end("tv 2D", box2d, 512, Method::Tv);
}
