//! `cargo bench --bench fig4_unroll_sched` — regenerates Figure 4:
//! naive vs +unroll vs +unroll+scheduling for every stencil, panels
//! (a)–(d).

// Lint policy for the blocking CI clippy job: `-D warnings` keeps the
// bug-finding groups (correctness, suspicious) and plain rustc warnings
// sharp, while the opinionated style/complexity/perf groups are allowed
// wholesale — this crate is grown in an offline container without a
// local toolchain, so purely stylistic findings cannot be run-and-fixed
// before landing.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use stencil_matrix::bench_harness::fig4;
use stencil_matrix::sim::SimConfig;
use stencil_matrix::util::bench::{fmt_secs, time_it};

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig::default();
    let (best, _) = time_it(1, || {
        for r in fig4::run_all(&cfg).expect("fig4") {
            r.emit().expect("emit");
        }
    });
    eprintln!("fig4 harness wall-clock: {}", fmt_secs(best));
    Ok(())
}
