"""AOT lowering: JAX → HLO *text* artifacts for the Rust PJRT runtime.

HLO text (not ``MLIR``/serialized proto) is the interchange format: jax
>= 0.5 emits HloModuleProto with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from .kernels.ref import Spec  # noqa: E402
from .model import make_evolve_fn, make_step_fn  # noqa: E402


def to_hlo_text(lowered) -> str:
    """Convert a jitted+lowered function to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# (name, spec, N, steps): steps == 1 emits the single-step function,
# steps > 1 the lax.scan evolution.
VARIANTS = [
    ("step_2d5p_n64", Spec(2, 1, "star"), 64, 1),
    ("step_2d9p_n64", Spec(2, 1, "box"), 64, 1),
    ("step_3d7p_n16", Spec(3, 1, "star"), 16, 1),
    ("evolve_2d5p_n64_t8", Spec(2, 1, "star"), 64, 8),
    ("evolve_2d5p_n256_t4", Spec(2, 1, "star"), 256, 4),
]


def lower_variant(name: str, spec: Spec, n: int, steps: int) -> tuple[str, dict]:
    ext = n + 2 * spec.order
    shape = (ext,) * spec.dims
    arg = jax.ShapeDtypeStruct(shape, jnp.float64)
    fn = (
        make_step_fn(spec, bn=min(128, n))
        if steps == 1
        else make_evolve_fn(spec, steps, bn=min(128, n))
    )
    lowered = jax.jit(fn).lower(arg)
    text = to_hlo_text(lowered)
    meta = {
        "name": name,
        "spec": {"dims": spec.dims, "order": spec.order, "kind": spec.kind},
        "n": n,
        "storage_extent": ext,
        "steps": steps,
        "dtype": "f64",
        "file": f"{name}.hlo.txt",
    }
    return text, meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="lower a single variant by name")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for name, spec, n, steps in VARIANTS:
        if args.only and name != args.only:
            continue
        text, meta = lower_variant(name, spec, n, steps)
        path = os.path.join(args.out_dir, meta["file"])
        with open(path, "w") as f:
            f.write(text)
        manifest.append(meta)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')} ({len(manifest)} variants)")


if __name__ == "__main__":
    main()
