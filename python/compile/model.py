"""Layer 2 — the JAX compute graph around the Pallas kernel.

Build-time only: this module is lowered once by ``aot.py`` to HLO text and
never imported at runtime. The Rust coordinator executes the lowered
artifacts over PJRT.

The "model" of a stencil paper is the time evolution itself: a single
stencil step (the L1 kernel plus the frozen-halo update) and a
``lax.scan`` multi-step evolution so one artifact execution advances many
steps without host round-trips (the L3 hot path amortizes dispatch
overhead across the scanned steps).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.outer_stencil import outer_stencil
from .kernels.ref import Spec, paper_default_coeffs


def stencil_step(
    spec: Spec,
    coeffs: np.ndarray,
    a: jnp.ndarray,
    *,
    bm: int = 8,
    bn: int = 128,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """One time step on a storage-shape array (halo stays frozen)."""
    if use_pallas:
        return outer_stencil(spec, coeffs, a, bm=bm, bn=bn, interpret=interpret)
    from .kernels import ref

    return ref.apply(spec, coeffs, a)


def evolve(
    spec: Spec,
    coeffs: np.ndarray,
    a: jnp.ndarray,
    steps: int,
    *,
    bm: int = 8,
    bn: int = 128,
    use_pallas: bool = True,
    interpret: bool = True,
    unroll: bool = False,
) -> jnp.ndarray:
    """``steps`` time steps.

    ``unroll=False`` uses ``lax.scan`` (one kernel trace — what you want
    under ``jax.jit`` in Python). ``unroll=True`` emits the steps inline:
    required for the AOT path, because xla_extension 0.5.1's HLO *text*
    parser mis-rounds-trips the ``while`` loops a scan lowers to (the
    re-assigned instruction ids break the nested loop computations), while
    straight-line HLO round-trips exactly.
    """
    if unroll:
        for _ in range(steps):
            a = stencil_step(
                spec, coeffs, a, bm=bm, bn=bn, use_pallas=use_pallas, interpret=interpret
            )
        return a

    def body(carry, _):
        nxt = stencil_step(
            spec, coeffs, carry, bm=bm, bn=bn, use_pallas=use_pallas, interpret=interpret
        )
        return nxt, ()

    out, _ = jax.lax.scan(body, a, None, length=steps)
    return out


def make_step_fn(spec: Spec, *, bm: int = 8, bn: int = 128, use_pallas: bool = True):
    """A unary function ``a -> (b,)`` with the repo-default coefficients
    baked in as constants (what the AOT artifacts export)."""
    coeffs = paper_default_coeffs(spec)

    def fn(a):
        return (stencil_step(spec, coeffs, a, bm=bm, bn=bn, use_pallas=use_pallas),)

    return fn


def make_evolve_fn(
    spec: Spec, steps: int, *, bm: int = 8, bn: int = 128, use_pallas: bool = True
):
    """A unary function ``a -> (b,)`` advancing ``steps`` steps (unrolled —
    see `evolve` for why the AOT artifacts cannot use lax.scan)."""
    coeffs = paper_default_coeffs(spec)

    def fn(a):
        return (
            evolve(spec, coeffs, a, steps, bm=bm, bn=bn, use_pallas=use_pallas, unroll=True),
        )

    return fn
