"""Pure-jnp gather-mode stencil oracle (Layer 1's correctness reference).

Replicates, bit-for-bit, the conventions of the Rust side
(``rust/src/stencil``):

- coefficient formula ``paper_default``: dense footprint index ``lin`` gets
  weight ``(3*lin + 5) % 11 + 1`` where the shape mask is non-zero, then
  the tensor is normalized by its *sequential* sum (matching Rust's
  ``iter().sum()`` fold order — pairwise summation would differ in the
  last ulp);
- grids carry an ``r``-deep frozen halo: arrays have storage shape
  ``(N + 2r)^d``, outputs are computed on the ``N^d`` interior, and the
  halo is copied from the input (Dirichlet-style frozen boundary).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Spec:
    """Stencil specification: dimension, shape kind, order."""

    dims: int
    order: int
    kind: str  # "box" | "star" | "diag"

    def __post_init__(self):
        assert self.dims in (2, 3)
        assert self.order >= 1
        assert self.kind in ("box", "star", "diag")
        assert not (self.kind == "diag" and self.dims != 2)

    @property
    def side(self) -> int:
        return 2 * self.order + 1

    def mask(self, off: tuple[int, ...]) -> bool:
        """Whether the dense footprint offset carries a non-zero weight."""
        if self.kind == "box":
            return True
        if self.kind == "star":
            return sum(1 for o in off if o != 0) <= 1
        return off[0] == off[1] or off[0] == -off[1]

    def dense_offsets(self) -> list[tuple[int, ...]]:
        r = self.order
        return list(itertools.product(range(-r, r + 1), repeat=self.dims))

    def name(self) -> str:
        nz = sum(1 for off in self.dense_offsets() if self.mask(off))
        return f"{self.dims}d{nz}p-{self.kind}-r{self.order}"


def paper_default_coeffs(spec: Spec) -> np.ndarray:
    """The repo-wide deterministic coefficient tensor (gather view)."""
    offs = spec.dense_offsets()
    data = np.zeros(len(offs), dtype=np.float64)
    for lin, off in enumerate(offs):
        if spec.mask(off):
            data[lin] = float((3 * lin + 5) % 11 + 1)
    # sequential sum to match Rust's fold exactly
    total = 0.0
    for v in data:
        total += float(v)
    data /= total
    return data.reshape((spec.side,) * spec.dims)


def apply(spec: Spec, coeffs: np.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """One gather-mode step on a storage-shape array (halo included).

    Interior points get Eq. (1); the halo stays frozen (copied from `a`).
    """
    r = spec.order
    n = a.shape[0] - 2 * r
    assert all(s == n + 2 * r for s in a.shape)
    acc = jnp.zeros((n,) * spec.dims, dtype=a.dtype)
    for off in spec.dense_offsets():
        lin = 0
        for o in off:
            lin = lin * spec.side + (o + r)
        c = float(coeffs.reshape(-1)[lin])
        if c == 0.0:
            continue
        sl = tuple(slice(r + o, r + o + n) for o in off)
        acc = acc + c * a[sl]
    interior = tuple(slice(r, r + n) for _ in range(spec.dims))
    return a.at[interior].set(acc)


def evolve(spec: Spec, coeffs: np.ndarray, a: jnp.ndarray, steps: int) -> jnp.ndarray:
    """`steps` gather-mode steps (ping-pong semantics, §2.2)."""
    for _ in range(steps):
        a = apply(spec, coeffs, a)
    return a
