"""Layer 1 — the paper's outer-product stencil as a Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets an
SME-like vector outer-product unit with explicit 8×8 matrix accumulators.
On TPU/Pallas the analogue is a VMEM accumulator tile updated by rank-1
products:

- the matrix-register tile      →  a ``(bm, bn)`` accumulator held in
  registers/VMEM for the whole inner loop of one grid step;
- ``FMOPA cv ⊗ av``             →  ``acc += cv[:, None] * av[None, :]``,
  which Mosaic maps onto the VPU/MXU;
- SME's EXT-based input-vector assembly →  static slices of the halo'ed
  input block (free at trace time: the shifted vectors of Eq. (12) are
  just different slices of the same VMEM-resident rows);
- multi-dimensional unrolling   →  the Pallas grid + block shape.

The kernel is expanded from the same coefficient-line machinery as the
Rust generator: a *parallel* cover (lines along the first non-unit-stride
dimension), one shifted coefficient vector per input position (Eq. (12)),
with statically-zero coefficient vectors skipped at trace time (what makes
star/diagonal shapes cheaper than box, §3.3).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO that any backend — and in
particular the Rust PJRT runtime — executes with identical numerics.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import Spec


def parallel_cover_lines(spec: Spec, coeffs: np.ndarray):
    """The parallel coefficient-line cover (§4.1, Table 1/2 row 1).

    Returns a list of ``(fixed_offsets, weights)`` where ``weights`` is the
    gather-orientation line (length ``2r+1``) and ``fixed_offsets`` the
    offsets in the non-line dimensions. Lines: 2D along dim 0, 3D along
    dim 1 — the choices with contiguous input vectors.
    """
    r = spec.order
    side = spec.side
    c = coeffs.reshape((side,) * spec.dims)
    lines = []
    if spec.dims == 2:
        for oj in range(-r, r + 1):
            w = c[:, oj + r]
            if np.any(w != 0.0):
                lines.append(((oj,), np.asarray(w, dtype=np.float64)))
    else:
        for oi in range(-r, r + 1):
            for ok in range(-r, r + 1):
                w = c[oi + r, :, ok + r]
                if np.any(w != 0.0):
                    lines.append(((oi, ok), np.asarray(w, dtype=np.float64)))
    return lines


def coeff_vector(weights: np.ndarray, p: int, bm: int) -> np.ndarray:
    """Eq. (12): ``cv[k] = w[(p - k) + r]`` when ``|p - k| <= r`` else 0."""
    r = (len(weights) - 1) // 2
    cv = np.zeros(bm, dtype=np.float64)
    for k in range(bm):
        d = p - k
        if -r <= d <= r:
            cv[k] = weights[d + r]
    return cv


def outer_stencil(
    spec: Spec,
    coeffs: np.ndarray,
    a: jnp.ndarray,
    *,
    bm: int = 8,
    bn: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """One stencil step on a storage-shape array via the outer-product
    formulation; returns the storage-shape result (frozen halo).

    ``bm`` plays the role of the matrix-register extent (8 on SME),
    ``bn`` the lane tile along the unit-stride dimension (wider on TPU,
    where the VPU register is 8×128).
    """
    r = spec.order
    n = a.shape[0] - 2 * r
    assert all(s == n + 2 * r for s in a.shape), "cubic storage shape"
    bn = min(bn, n)
    bm_eff = min(bm, n)
    assert n % bm_eff == 0 and n % bn == 0, f"block {bm_eff}x{bn} must tile N={n}"
    lines = parallel_cover_lines(spec, coeffs)
    # cv table input: (line, p+r) -> (bm,) vector. Statically-zero vectors
    # are skipped at trace time via the host-side copy `cvs`.
    cvs = {
        (li, p): coeff_vector(w, p, bm_eff)
        for li, (_, w) in enumerate(lines)
        for p in range(-r, bm_eff + r)
    }
    cv_table = np.zeros((len(lines), bm_eff + 2 * r, bm_eff), dtype=np.float64)
    for (li, p), cv in cvs.items():
        cv_table[li, p + r] = cv
    cv_table = jnp.asarray(cv_table, dtype=a.dtype)

    if spec.dims == 2:
        grid = (n // bm_eff, n // bn)

        def kernel(a_ref, cv_ref, o_ref):
            ti = pl.program_id(0)
            tj = pl.program_id(1)
            acc = jnp.zeros((bm_eff, bn), dtype=a_ref.dtype)
            for li, ((oj,), _w) in enumerate(lines):
                for p in range(-r, bm_eff + r):
                    if not np.any(cvs[(li, p)] != 0.0):
                        continue  # statically zero (Eq. 12 skip)
                    cv = cv_ref[li, p + r]
                    row = a_ref[ti * bm_eff + p + r, pl.dslice(tj * bn + oj + r, bn)]
                    acc = acc + cv[:, None] * row[None, :]
            o_ref[...] = acc

        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec(a.shape, lambda i, j: (0, 0)),
                pl.BlockSpec(cv_table.shape, lambda i, j: (0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((bm_eff, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
            interpret=interpret,
        )(a, cv_table)
        return a.at[r : r + n, r : r + n].set(out)

    grid = (n, n // bm_eff, n // bn)

    def kernel3(a_ref, cv_ref, o_ref):
        i = pl.program_id(0)
        tj = pl.program_id(1)
        tk = pl.program_id(2)
        acc = jnp.zeros((bm_eff, bn), dtype=a_ref.dtype)
        for li, ((oi, ok), _w) in enumerate(lines):
            for p in range(-r, bm_eff + r):
                if not np.any(cvs[(li, p)] != 0.0):
                    continue
                cv = cv_ref[li, p + r]
                row = a_ref[
                    i + oi + r,
                    tj * bm_eff + p + r,
                    pl.dslice(tk * bn + ok + r, bn),
                ]
                acc = acc + cv[:, None] * row[None, :]
        o_ref[0, ...] = acc

    out = pl.pallas_call(
        kernel3,
        grid=grid,
        in_specs=[
            pl.BlockSpec(a.shape, lambda i, j, k: (0, 0, 0)),
            pl.BlockSpec(cv_table.shape, lambda i, j, k: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm_eff, bn), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((n, n, n), a.dtype),
        interpret=interpret,
    )(a, cv_table)
    return a.at[r : r + n, r : r + n, r : r + n].set(out)
