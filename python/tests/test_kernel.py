"""L1 correctness: the Pallas outer-product kernel vs the pure-jnp oracle.

The CORE correctness signal of the Python layer: hypothesis sweeps the
(spec, size, block, seed) space and asserts elementwise agreement.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.outer_stencil import (
    coeff_vector,
    outer_stencil,
    parallel_cover_lines,
)
from compile.kernels.ref import Spec, paper_default_coeffs


def grid_for(spec: Spec, n: int, seed: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    shape = (n + 2 * spec.order,) * spec.dims
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=shape))


SPECS_2D = [
    Spec(2, 1, "box"),
    Spec(2, 2, "box"),
    Spec(2, 3, "box"),
    Spec(2, 1, "star"),
    Spec(2, 2, "star"),
    Spec(2, 3, "star"),
    Spec(2, 1, "diag"),
    Spec(2, 2, "diag"),
]
SPECS_3D = [
    Spec(3, 1, "box"),
    Spec(3, 2, "box"),
    Spec(3, 1, "star"),
    Spec(3, 2, "star"),
    Spec(3, 3, "star"),
]


@pytest.mark.parametrize("spec", SPECS_2D, ids=lambda s: s.name())
def test_kernel_matches_ref_2d(spec):
    coeffs = paper_default_coeffs(spec)
    a = grid_for(spec, 16, 42)
    got = outer_stencil(spec, coeffs, a, bm=4, bn=8)
    want = ref.apply(spec, coeffs, a)
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-13)


@pytest.mark.parametrize("spec", SPECS_3D, ids=lambda s: s.name())
def test_kernel_matches_ref_3d(spec):
    coeffs = paper_default_coeffs(spec)
    a = grid_for(spec, 8, 7)
    got = outer_stencil(spec, coeffs, a, bm=4, bn=8)
    want = ref.apply(spec, coeffs, a)
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-13)


@settings(max_examples=25, deadline=None)
@given(
    dims=st.sampled_from([2, 3]),
    order=st.integers(1, 3),
    kind=st.sampled_from(["box", "star"]),
    nblocks=st.integers(1, 3),
    bm=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(dims, order, kind, nblocks, bm, seed):
    spec = Spec(dims, order, kind)
    n = bm * nblocks
    if dims == 3 and n > 16:
        n = 16 if 16 % bm == 0 else bm * 2
    coeffs = paper_default_coeffs(spec)
    a = grid_for(spec, n, seed)
    got = outer_stencil(spec, coeffs, a, bm=bm, bn=n)
    want = ref.apply(spec, coeffs, a)
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-13)


def test_halo_is_frozen():
    spec = Spec(2, 1, "star")
    coeffs = paper_default_coeffs(spec)
    a = grid_for(spec, 16, 3)
    got = outer_stencil(spec, coeffs, a, bm=8, bn=16)
    np.testing.assert_array_equal(got[0, :], a[0, :])
    np.testing.assert_array_equal(got[-1, :], a[-1, :])
    np.testing.assert_array_equal(got[:, 0], a[:, 0])
    np.testing.assert_array_equal(got[:, -1], a[:, -1])


def test_coeffs_normalized_and_masked():
    for spec in SPECS_2D + SPECS_3D:
        c = paper_default_coeffs(spec)
        assert abs(c.sum() - 1.0) < 1e-12
        nz = int(np.count_nonzero(c))
        if spec.kind == "box":
            assert nz == spec.side ** spec.dims
        elif spec.kind == "star":
            assert nz == 2 * spec.order * spec.dims + 1
        else:
            assert nz == 4 * spec.order + 1


def test_constant_field_is_fixed_point():
    spec = Spec(2, 2, "box")
    coeffs = paper_default_coeffs(spec)
    a = jnp.full((20, 20), 3.25, dtype=jnp.float64)
    got = outer_stencil(spec, coeffs, a, bm=8, bn=16)
    np.testing.assert_allclose(got, a, atol=1e-12)


def test_parallel_cover_counts():
    # Table 1 / Table 2 line counts
    assert len(parallel_cover_lines(Spec(2, 1, "box"), paper_default_coeffs(Spec(2, 1, "box")))) == 3
    assert len(parallel_cover_lines(Spec(2, 2, "star"), paper_default_coeffs(Spec(2, 2, "star")))) == 5
    assert len(parallel_cover_lines(Spec(3, 1, "box"), paper_default_coeffs(Spec(3, 1, "box")))) == 9
    assert len(parallel_cover_lines(Spec(3, 1, "star"), paper_default_coeffs(Spec(3, 1, "star")))) == 5


def test_coeff_vector_eq12():
    w = np.array([1.0, 2.0, 3.0])  # r = 1, gather orientation
    # p = 0: k=0 -> w[0-0+1]=2 ; k=1 -> w[0-1+1]=1
    np.testing.assert_array_equal(coeff_vector(w, 0, 4), [2.0, 1.0, 0.0, 0.0])
    # p = -1: only k=0 gets w[-1-0+1]=w[0]=1
    np.testing.assert_array_equal(coeff_vector(w, -1, 4), [1.0, 0.0, 0.0, 0.0])
    # p = 4 (= bm-1+r): only k=3 gets w[4-3+1]=w[2]=3
    np.testing.assert_array_equal(coeff_vector(w, 4, 4), [0.0, 0.0, 0.0, 3.0])


def test_matches_rust_coefficients():
    # The dense-index formula must match rust's paper_default exactly:
    # ((3*lin + 5) % 11 + 1) masked, sequentially normalized.
    spec = Spec(2, 1, "box")
    c = paper_default_coeffs(spec).reshape(-1)
    raw = np.array([(3 * i + 5) % 11 + 1 for i in range(9)], dtype=np.float64)
    total = 0.0
    for v in raw:
        total += v
    np.testing.assert_array_equal(c, raw / total)
