"""L2 correctness: the scan-based evolution and the AOT lowering path."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref
from compile.kernels.ref import Spec, paper_default_coeffs


def grid_for(spec, n, seed):
    rng = np.random.default_rng(seed)
    shape = (n + 2 * spec.order,) * spec.dims
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=shape))


def test_evolve_matches_repeated_apply():
    spec = Spec(2, 1, "star")
    coeffs = paper_default_coeffs(spec)
    a = grid_for(spec, 16, 5)
    got = model.evolve(spec, coeffs, a, 4, bm=8, bn=16)
    want = ref.evolve(spec, coeffs, a, 4)
    np.testing.assert_allclose(got, want, atol=1e-12)


def test_evolve_3d():
    spec = Spec(3, 1, "box")
    coeffs = paper_default_coeffs(spec)
    a = grid_for(spec, 8, 6)
    got = model.evolve(spec, coeffs, a, 2, bm=4, bn=8)
    want = ref.evolve(spec, coeffs, a, 2)
    np.testing.assert_allclose(got, want, atol=1e-12)


def test_step_fn_tuple_output():
    spec = Spec(2, 1, "star")
    fn = model.make_step_fn(spec, bn=16)
    a = grid_for(spec, 16, 1)
    out = fn(a)
    assert isinstance(out, tuple) and len(out) == 1
    want = ref.apply(spec, paper_default_coeffs(spec), a)
    np.testing.assert_allclose(out[0], want, atol=1e-12)


@pytest.mark.parametrize("name,spec,n,steps", aot.VARIANTS[:3], ids=lambda v: str(v))
def test_lowering_produces_hlo_text(name, spec, n, steps):
    text, meta = aot.lower_variant(name, spec, n, steps)
    assert text.startswith("HloModule")
    assert meta["storage_extent"] == n + 2 * spec.order
    # the entry computation must take one f64 array and return a tuple
    assert "f64[" in text


def test_lowered_numerics_roundtrip():
    # compile the lowered HLO text back through XLA and compare against
    # the oracle — the same numerics the Rust runtime will see.
    from jax._src.lib import xla_client as xc

    spec = Spec(2, 1, "star")
    n = 16
    fn = model.make_step_fn(spec, bn=16)
    a = grid_for(spec, n, 9)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct(a.shape, jnp.float64))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    got = jax.jit(fn)(a)[0]
    want = ref.apply(spec, paper_default_coeffs(spec), a)
    np.testing.assert_allclose(got, want, atol=1e-12)
    _ = xc  # conversion exercised in aot.to_hlo_text above
